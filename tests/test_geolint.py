"""geolint rule coverage: each GL rule fires exactly where expected
(violating / compliant / allowlisted fixture per rule), the CLI exit
codes hold, and the full repo lints clean.

Fixtures are linted through ``lint_source`` with synthetic repo-relative
paths (``src/repro/serve/x.py`` etc.) — scope resolution recovers the
tail from anywhere in a path, so no checkout layout is required.
"""
import pathlib
import subprocess
import sys
import textwrap

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.geolint import lint_paths, lint_source  # noqa: E402


def fired(src, path):
    """[(rule, line)] for dedented ``src`` linted as ``path``."""
    return [
        (v.rule, v.line) for v in lint_source(textwrap.dedent(src), path)
    ]


def rules(src, path):
    return {r for r, _ in fired(src, path)}


# ------------------------------------------------------------------- GL001
def test_gl001_fires_on_mutated_module_dict():
    src = """\
    _CACHE = {}


    def put(k, v):
        _CACHE[k] = v
    """
    assert fired(src, "src/repro/core/x.py") == [("GL001", 1)]


def test_gl001_fires_on_global_rebound_singleton():
    src = """\
    _STATE = None


    def set_state(s):
        global _STATE
        _STATE = s
    """
    assert fired(src, "src/repro/core/x.py") == [("GL001", 1)]


def test_gl001_never_mutated_constant_is_compliant():
    src = """\
    _TABLE = {"us-east": 0.07, "eu-west": 0.09}


    def price(region):
        return _TABLE[region]
    """
    assert fired(src, "src/repro/core/x.py") == []


def test_gl001_allowlist_requires_reset_exposure():
    no_reset = """\
    _CACHE = {}  # geolint: allow[GL001]


    def put(k, v):
        _CACHE[k] = v
    """
    # pragma without a reset path still fires (with a different message)
    vs = lint_source(textwrap.dedent(no_reset), "src/repro/core/x.py")
    assert [(v.rule, v.line) for v in vs] == [("GL001", 1)]
    assert "reset()" in vs[0].message

    with_reset = textwrap.dedent(no_reset) + (
        "\n\ndef reset_cache():\n    _CACHE.clear()\n"
    )
    assert lint_source(with_reset, "src/repro/core/x.py") == []


def test_gl001_allowlist_accepts_class_with_reset_method():
    src = """\
    class Tuner:
        def reset(self):
            self.t = {}


    _TUNER = Tuner()  # geolint: allow[GL001]


    def set_tuner(t):
        global _TUNER
        _TUNER = t
    """
    assert fired(src, "src/repro/core/x.py") == []


def test_gl001_out_of_scope_path_is_ignored():
    src = """\
    _CACHE = {}


    def put(k, v):
        _CACHE[k] = v
    """
    assert fired(src, "benchmarks/x.py") == []


# ------------------------------------------------------------------- GL002
def test_gl002_fires_on_clock_calls_and_unseeded_rng():
    src = """\
    import time
    import numpy as np


    def step():
        t0 = time.perf_counter()
        t1 = time.time()
        rng = np.random.default_rng()
        x = np.random.rand(3)
        return t0, t1, rng, x
    """
    assert fired(src, "src/repro/serve/x.py") == [
        ("GL002", 6), ("GL002", 7), ("GL002", 8), ("GL002", 9),
    ]
    # same code is fine outside the control-plane scope
    assert fired(src, "src/repro/core/x.py") == []
    # migration.py is the one in-scope streaming file
    assert rules(src, "src/repro/streaming/migration.py") == {"GL002"}
    assert fired(src, "src/repro/streaming/mutation_log.py") == []


def test_gl002_injection_defaults_and_seeded_rng_are_compliant():
    src = """\
    import time
    import numpy as np


    def __init__(self, clock=time.perf_counter, rng=None):
        self._clock = clock
        self._rng = rng or np.random.default_rng(0)
    """
    assert fired(src, "src/repro/serve/x.py") == []


def test_gl002_pragma_suppresses():
    src = """\
    import time


    def step():
        return time.time()  # geolint: allow[GL002]
    """
    assert fired(src, "src/repro/serve/x.py") == []


# ------------------------------------------------------------------- GL003
def test_gl003_fires_on_foreign_heat_writes():
    src = """\
    def diffuse(caches, h, decay):
        for c, row in zip(caches, h):
            c.heat[:4] = row
            c.heat[4:] *= decay
        caches[0].heat = h[0]
    """
    assert fired(src, "src/repro/core/x.py") == [
        ("GL003", 3), ("GL003", 4), ("GL003", 5),
    ]


def test_gl003_demand_scope_and_plain_self_attr_are_compliant():
    src = """\
    class StreamingHeat:
        def __init__(self, n):
            self.heat = [0.0] * n

        def decay(self, g):
            self.heat = [h * g for h in self.heat]
    """
    assert fired(src, "src/repro/core/x.py") == []
    writer = """\
    def deposit(self, row, vals):
        self.heat[row] = vals
    """
    assert fired(writer, "src/repro/demand/od_layer.py") == []


def test_gl003_self_write_through_property_fires():
    src = """\
    class HeatCache:
        @property
        def heat(self):
            return self.demand.heat[self._row]

        def evict(self):
            self.heat[:] = 0.0
    """
    assert fired(src, "src/repro/core/x.py") == [("GL003", 7)]


def test_gl003_pragma_suppresses():
    src = """\
    def poke(cache):
        cache.heat[0] += 1.0  # geolint: allow[GL003]
    """
    assert fired(src, "tests/test_x.py") == []


# ------------------------------------------------------------------- GL004
def test_gl004_fires_on_string_keyed_lookup_in_loop():
    src = """\
    def settle(reg, entries):
        for e in entries:
            reg.counter("placement.hit", dc=e.dc).inc(e.hits)
            while e.pending:
                reg.histogram("wave_s").observe(e.pending.pop())
    """
    assert fired(src, "src/repro/serve/x.py") == [
        ("GL004", 3), ("GL004", 5),
    ]
    assert fired(src, "src/repro/core/routing.py") == [
        ("GL004", 3), ("GL004", 5),
    ]
    # out of the hot-path scope: placement, demand, kernels are exempt
    assert fired(src, "src/repro/core/placement.py") == []


def test_gl004_hoisted_handles_and_keyed_accessors_are_compliant():
    src = """\
    def settle(reg, entries, key):
        h = reg.counter("placement.hit")
        total = 0
        for e in entries:
            h.inc(e.hits)
            reg.counter_keyed("placement.hit", key).inc(e.hits)
            total += e.hits
        reg.counter("placement.total").inc(total)
    """
    assert fired(src, "src/repro/serve/x.py") == []


def test_gl004_nested_function_in_loop_is_not_flagged():
    src = """\
    def build(reg, entries):
        thunks = []
        for e in entries:
            def emit():
                reg.counter("cold.path").inc()
            thunks.append(emit)
        return thunks
    """
    assert fired(src, "src/repro/serve/x.py") == []


# ------------------------------------------------------------------- GL005
def test_gl005_fires_in_jit_and_kernel_bodies():
    src = """\
    import functools

    import jax
    import numpy as np
    from jax.experimental import pallas as pl


    @jax.jit
    def f(x):
        print("tracing", x)
        return np.sum(x)


    @functools.partial(jax.jit, static_argnames=("n",))
    def g(x, n):
        return x.astype(np.float64)


    def _kern(x_ref, o_ref):
        global _COUNT
        o_ref[...] = x_ref[...]


    def launch(x):
        return pl.pallas_call(_kern, out_shape=x)(x)
    """
    got = fired(src, "src/repro/kernels/x.py")
    assert ("GL005", 10) in got  # print in @jax.jit
    assert ("GL005", 11) in got  # host np.sum on traced value
    assert ("GL005", 16) in got  # np.float64 in partial-jit fn
    assert ("GL005", 20) in got  # global in kernel body
    # untraced helpers in the same file may use numpy freely
    assert all(line != 24 for _, line in got)


def test_gl005_clean_kernel_and_out_of_scope_are_compliant():
    src = """\
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl


    def _kern(x_ref, o_ref):
        o_ref[...] = jnp.maximum(x_ref[...], 0.0)


    @functools.partial(jax.jit, static_argnames=("block",))
    def relu(x, block):
        return pl.pallas_call(_kern, out_shape=x)(x)
    """
    assert fired(src, "src/repro/kernels/x.py") == []
    dirty = """\
    import jax
    import numpy as np


    @jax.jit
    def f(x):
        return np.sum(x)
    """
    assert fired(dirty, "src/repro/core/x.py") == []  # kernels/ only


def test_gl005_pragma_suppresses():
    src = """\
    import jax
    import numpy as np


    @jax.jit
    def f(x, shape):
        n = np.prod(shape)  # geolint: allow[GL005] — static shape math
        return x.reshape(n)
    """
    assert fired(src, "src/repro/kernels/x.py") == []


# ------------------------------------------------------------------- GL006
def test_gl006_fires_on_unguarded_rekey():
    src = """\
    class GeoGraphStore:
        def compact(self, keep):
            self._item_uid = self._item_uid[keep]
    """
    vs = lint_source(textwrap.dedent(src), "src/repro/core/store.py")
    assert [(v.rule, v.line) for v in vs] == [("GL006", 3)]
    assert "_fire_remap_listeners" in vs[0].message
    assert "_id_epoch" in vs[0].message


def test_gl006_guarded_rekey_and_init_are_compliant():
    src = """\
    class GeoGraphStore:
        def __init__(self, n):
            self._item_uid = list(range(n))
            self._id_epoch = 0

        def compact(self, keep, imap):
            self._item_uid = self._item_uid[keep]
            self._id_epoch += 1
            self._fire_remap_listeners(imap)
    """
    assert fired(src, "src/repro/core/store.py") == []


def test_gl006_other_classes_are_exempt():
    src = """\
    class ShadowStore:
        def compact(self, keep):
            self._item_uid = self._item_uid[keep]
    """
    assert fired(src, "src/repro/core/x.py") == []


# ------------------------------------------------------- engine behaviors
def test_syntax_error_reports_gl000():
    vs = lint_source("def broken(:\n", "src/repro/core/x.py")
    assert [v.rule for v in vs] == ["GL000"]


def test_cli_exit_codes_and_diagnostics(tmp_path):
    """Seeded violations for all six rules exit non-zero with file:line
    diagnostics; a clean tree exits 0 (the CI-gate contract)."""
    seeds = {
        "src/repro/core/gl1.py": "_C = {}\n\n\ndef put(k, v):\n    _C[k] = v\n",
        "src/repro/serve/gl2.py": (
            "import time\n\n\ndef f():\n    return time.time()\n"
        ),
        "src/repro/core/gl3.py": "def f(c):\n    c.heat[0] = 1.0\n",
        "src/repro/serve/gl4.py": (
            "def f(reg, xs):\n    for x in xs:\n"
            "        reg.counter('a').inc(x)\n"
        ),
        "src/repro/kernels/gl5.py": (
            "import jax\nimport numpy as np\n\n\n@jax.jit\ndef f(x):\n"
            "    return np.sum(x)\n"
        ),
        "src/repro/core/gl6.py": (
            "class GeoGraphStore:\n    def rekey(self, keep):\n"
            "        self._item_uid = self._item_uid[keep]\n"
        ),
    }
    for rel, body in seeds.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(body)
    proc = subprocess.run(
        [sys.executable, "-m", "tools.geolint", str(tmp_path / "src"),
         "--json", str(tmp_path / "report.json")],
        cwd=str(REPO_ROOT), capture_output=True, text=True,
    )
    assert proc.returncode == 1
    for rule in ("GL001", "GL002", "GL003", "GL004", "GL005", "GL006"):
        assert rule in proc.stdout, f"{rule} missing from CLI output"
    # file:line:col diagnostics
    assert "gl1.py:1:0: GL001" in proc.stdout
    assert (tmp_path / "report.json").exists()

    clean = tmp_path / "clean"
    clean.mkdir()
    (clean / "ok.py").write_text("X = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.geolint", str(clean)],
        cwd=str(REPO_ROOT), capture_output=True, text=True,
    )
    assert proc.returncode == 0
    assert proc.stdout.strip() == ""


def test_full_repo_lints_clean():
    """The CI gate: the tree as committed has zero violations."""
    vs = lint_paths(
        [str(REPO_ROOT / d) for d in ("src", "tests", "benchmarks")]
    )
    assert vs == [], "\n".join(v.format() for v in vs)
