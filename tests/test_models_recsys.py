import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recsys.bst import (
    BSTSpec,
    bst_forward,
    bst_init,
    bst_user_state,
    retrieval_score,
)

SPEC = BSTSpec(n_items=512, n_cats=32, embed_dim=16, seq_len=8,
               n_blocks=1, n_heads=2, mlp_dims=(32, 16))


def _batch(B=8, seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        hist_items=jnp.asarray(rng.integers(0, 512, (B, 8))),
        hist_cats=jnp.asarray(rng.integers(0, 32, (B, 8))),
        target_item=jnp.asarray(rng.integers(0, 512, B)),
        target_cat=jnp.asarray(rng.integers(0, 32, B)),
        label=jnp.asarray(rng.random(B) < 0.3, jnp.float32),
    )


def test_forward_shapes():
    p = bst_init(jax.random.PRNGKey(0), SPEC)
    logits = bst_forward(p, _batch(), SPEC)
    assert logits.shape == (8,)
    assert bool(jnp.isfinite(logits).all())


def test_target_sensitivity():
    """Different target items change the CTR logit (sequence attends target)."""
    p = bst_init(jax.random.PRNGKey(0), SPEC)
    b = _batch()
    l1 = bst_forward(p, b, SPEC)
    b2 = dict(b, target_item=(b["target_item"] + 7) % 512)
    l2 = bst_forward(p, b2, SPEC)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_retrieval_ranks_history_item():
    p = bst_init(jax.random.PRNGKey(0), SPEC)
    b = _batch(B=4)
    u = bst_user_state(p, b, SPEC)
    cands = jnp.asarray(np.random.default_rng(1).integers(0, 512, (4, 64)))
    scores = retrieval_score(p, u, cands)
    assert scores.shape == (4, 64)
    assert bool(jnp.isfinite(scores).all())
