import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.core.patterns import (
    Pattern,
    _decompose_overlap_regions_py,
    decompose_overlap_regions,
)


def test_khop_patterns_valid(small_setup):
    g, env, csr, wl, pats = small_setup
    for p in pats:
        assert len(p.items) > 0
        verts = p.items[p.items < g.n_nodes]
        edges = p.items[p.items >= g.n_nodes] - g.n_nodes
        assert (verts < g.n_nodes).all()
        assert (edges < g.n_edges).all()
        assert p.read_rate > 0
        assert 0 < p.eta <= 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_overlap_regions_partition(seed):
    """Venn regions partition the union of pattern items (disjoint + cover)."""
    rng = np.random.default_rng(seed)
    n_items = 60
    pats = [
        Pattern(i, np.unique(rng.integers(0, n_items, 15)),
                r_py=np.ones(2), w_py=np.zeros(2))
        for i in range(4)
    ]
    regions = decompose_overlap_regions(pats, n_items)
    all_items = np.unique(np.concatenate([p.items for p in pats]))
    region_items = np.concatenate([r.items for r in regions])
    assert len(region_items) == len(np.unique(region_items))  # disjoint
    assert set(region_items) == set(all_items)  # cover
    # each region's key matches membership exactly
    for r in regions:
        for x in r.items:
            member = tuple(sorted(p.pid for p in pats if x in set(p.items.tolist())))
            assert member == r.key


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_decompose_vectorized_matches_reference(seed):
    """The packed-bitmask np.unique decomposition == the per-item membership
    dict, region for region (rid, key, items, degree)."""
    rng = np.random.default_rng(seed)
    n_items = 80
    pats = [
        Pattern(i, np.unique(rng.integers(0, n_items, int(rng.integers(1, 25)))),
                r_py=np.ones(2), w_py=np.zeros(2))
        for i in range(int(rng.integers(1, 9)))
    ]
    vec = decompose_overlap_regions(pats, n_items)
    ref = _decompose_overlap_regions_py(pats, n_items)
    assert len(vec) == len(ref)
    for a, b in zip(vec, ref):
        assert a.rid == b.rid
        assert a.key == b.key
        assert a.degree == b.degree
        assert np.array_equal(a.items, b.items)
        assert a.items.dtype == b.items.dtype


def test_decompose_vectorized_on_khop_workload(small_setup):
    """Oracle check on the realistic generator output (the placement input)."""
    g, env, csr, wl, pats = small_setup
    vec = decompose_overlap_regions(pats, g.n_items)
    ref = _decompose_overlap_regions_py(pats, g.n_items)
    assert [(r.rid, r.key) for r in vec] == [(r.rid, r.key) for r in ref]
    for a, b in zip(vec, ref):
        assert np.array_equal(a.items, b.items)


def test_decompose_edge_cases():
    assert decompose_overlap_regions([], 10) == []
    empty = Pattern(0, np.zeros(0, np.int64), r_py=np.ones(2), w_py=np.zeros(2))
    assert decompose_overlap_regions([empty], 10) == []
    one = Pattern(3, np.asarray([5, 7]), r_py=np.ones(2), w_py=np.zeros(2))
    (r,) = decompose_overlap_regions([empty, one], 10)
    assert r.key == (3,) and np.array_equal(r.items, [5, 7]) and r.degree == 1


def test_aggregate_frequencies(small_setup):
    g, env, csr, wl, pats = small_setup
    # per-item frequency = sum over patterns containing it
    x = int(pats[0].items[0])
    expect = sum(p.r_py for p in pats if x in set(p.items.tolist()))
    np.testing.assert_allclose(wl.r_xy[x], expect)
