import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.core.graph import build_csr
from repro.core.patterns import (
    Pattern,
    Workload,
    decompose_overlap_regions,
    generate_khop_patterns,
    region_adjacency,
)
from repro.data.synthetic import make_benchmark_graph


def test_khop_patterns_valid(small_setup):
    g, env, csr, wl, pats = small_setup
    for p in pats:
        assert len(p.items) > 0
        verts = p.items[p.items < g.n_nodes]
        edges = p.items[p.items >= g.n_nodes] - g.n_nodes
        assert (verts < g.n_nodes).all()
        assert (edges < g.n_edges).all()
        assert p.read_rate > 0
        assert 0 < p.eta <= 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_overlap_regions_partition(seed):
    """Venn regions partition the union of pattern items (disjoint + cover)."""
    rng = np.random.default_rng(seed)
    n_items = 60
    pats = [
        Pattern(i, np.unique(rng.integers(0, n_items, 15)),
                r_py=np.ones(2), w_py=np.zeros(2))
        for i in range(4)
    ]
    regions = decompose_overlap_regions(pats, n_items)
    all_items = np.unique(np.concatenate([p.items for p in pats]))
    region_items = np.concatenate([r.items for r in regions])
    assert len(region_items) == len(np.unique(region_items))  # disjoint
    assert set(region_items) == set(all_items)  # cover
    # each region's key matches membership exactly
    for r in regions:
        for x in r.items:
            member = tuple(sorted(p.pid for p in pats if x in set(p.items.tolist())))
            assert member == r.key


def test_aggregate_frequencies(small_setup):
    g, env, csr, wl, pats = small_setup
    # per-item frequency = sum over patterns containing it
    x = int(pats[0].items[0])
    expect = sum(p.r_py for p in pats if x in set(p.items.tolist()))
    np.testing.assert_allclose(wl.r_xy[x], expect)
