import numpy as np

from repro.core.graph import Graph, weakly_connected_components
from repro.core.layered_graph import build_layered_graph


def _toy():
    # 6 vertices across 3 DCs; cross edges with different latencies
    g = Graph.from_edges(
        6,
        src=[0, 2, 1, 3, 0],
        dst=[1, 3, 2, 4, 5],
        partition=[0, 0, 1, 1, 2, 2],
    )
    return g


def test_wcc():
    lab = weakly_connected_components(5, np.array([0, 3]), np.array([1, 4]))
    assert lab[0] == lab[1]
    assert lab[3] == lab[4]
    assert lab[0] != lab[2] != lab[3]


def test_edge_layers_monotone(small_setup):
    g, env, *_ = small_setup
    lg = build_layered_graph(g, env)
    # intra-DC edges at layer 0; cross edges in 1..h
    cross = g.partition[g.src] != g.partition[g.dst]
    assert (lg.edge_layer[~cross] == 0).all()
    assert (lg.edge_layer[cross] >= 1).all()
    # mean latency increases with layer (where layers are populated)
    lat = [lg.mean_layer_latency[i] for i in range(1, lg.n_layers + 1)
           if (lg.edge_layer == i).any()]
    assert all(a < b for a, b in zip(lat, lat[1:]))


def test_components_coarsen(small_setup):
    g, env, *_ = small_setup
    lg = build_layered_graph(g, env)
    for i in range(1, lg.n_layers + 1):
        n_prev = len(np.unique(lg.comp_of_dc[i - 1]))
        n_cur = len(np.unique(lg.comp_of_dc[i]))
        assert n_cur <= n_prev  # merging only


def test_bridge_subgraph_edges_match_layer(small_setup):
    g, env, *_ = small_setup
    lg = build_layered_graph(g, env)
    for i in range(1, lg.n_layers + 1):
        for b in lg.layers[i]:
            assert (lg.edge_layer[b.edge_ids] == i).all()
            assert b.n_dcs >= 1
            # children were distinct comps at i-1
            assert len(set(b.children)) == len(b.children)


def test_layer_for_latency(small_setup):
    g, env, *_ = small_setup
    lg = build_layered_graph(g, env)
    assert lg.layer_for_latency(0.0001) == 1
    assert lg.layer_for_latency(10.0) == lg.n_layers
    # monotone
    ls = [lg.layer_for_latency(x) for x in [0.01, 0.11, 0.21, 0.5]]
    assert ls == sorted(ls)
