"""Telemetry subsystem (repro.obs): registry, tracer, exporters.

Bars under test:
  * P² histograms track numpy's exact quantiles on random streams, for the
    scalar ``observe`` path AND the batch ``observe_many`` path (including
    the sorted-batch marker seeding and heavily tied streams);
  * a disabled registry is a true no-op (shared singleton, nothing stored),
    and span context managers still measure elapsed time when tracing is
    off (report timing fields must not go to zero);
  * span nesting/parenting follows the context-manager stack, and explicit
    ``record()`` spans parent onto returned sids;
  * the Chrome trace-event export is deterministic under the scheduler's
    simulated clock: two identical runs serialize byte-identically;
  * scheduler miss-by-cause counts partition ``deadline_misses`` exactly
    and per-origin p99s cover every served origin (the BENCH_scheduler
    report fields);
  * store reports (``apply_time_s``) are sourced from the span tree.
"""
import json
import math

import numpy as np
import pytest

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.obs import (
    Histogram,
    MetricsRegistry,
    P2Quantile,
    Tracer,
    export_chrome_trace,
    set_default_registry,
    text_dashboard,
)
from repro.obs.metrics import _NOOP
from repro.serve import AdmissionConfig, AdmissionController
from repro.serve.scheduler import SimClock
from repro.streaming import DeltaGraph, random_churn_batch


# ---------------------------------------------------------------- registry
def test_counter_gauge_identity_and_snapshot():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("requests", origin=3)
    c.inc()
    c.inc(4.0)
    assert reg.counter("requests", origin=3) is c  # keyed identity
    assert c.value == 5.0
    reg.gauge("watermark").set(7.5)
    snap = reg.snapshot()
    assert snap["requests"]["origin=3"] == {"type": "counter", "value": 5.0}
    assert snap["watermark"]["-"]["value"] == 7.5
    reg.reset()
    assert reg.counter("requests", origin=3).value == 0.0
    assert math.isnan(reg.gauge("watermark").value)


def test_merge_folds_counters_gauges_histograms():
    regs = [MetricsRegistry(enabled=True) for _ in range(3)]
    rng = np.random.default_rng(0)
    samples = []
    for i, reg in enumerate(regs):
        reg.counter("serving.requests").inc(10.0 * (i + 1))
        reg.counter("hits", layer=i % 2).inc(1.0)
        reg.gauge("watermark").set(float(i))
        s = rng.random(500)
        samples.append(s)
        reg.histogram("lat", quantiles=(0.5, 0.99)).observe_many(s)
    merged = MetricsRegistry.merge([r.snapshot() for r in regs])
    assert merged["serving.requests"]["-"]["value"] == 60.0
    # per-tag counters fold per tag, not globally
    assert merged["hits"]["layer=0"]["value"] == 2.0
    assert merged["hits"]["layer=1"]["value"] == 1.0
    # gauges: last non-NaN wins (point-in-time reading)
    assert merged["watermark"]["-"]["value"] == 2.0
    h = merged["lat"]["-"]
    allv = np.concatenate(samples)
    assert h["count"] == len(allv)
    assert h["sum"] == pytest.approx(allv.sum())
    assert h["min"] == pytest.approx(allv.min())
    assert h["max"] == pytest.approx(allv.max())
    # count-weighted quantile fold stays near the pooled-stream quantile
    assert h["quantiles"]["p50"] == pytest.approx(
        np.quantile(allv, 0.5), abs=0.05
    )
    # the internal weighting scratch must not leak into the snapshot
    assert "_qweight" not in h


def test_merge_grids_disjoint_inputs_and_type_clashes():
    a, b = MetricsRegistry(enabled=True), MetricsRegistry(enabled=True)
    a.counter_grid("wan", axes=("src", "dst")).add(np.array([[0.0, 3.0], [0.0, 0.0]]))
    b.counter_grid("wan", axes=("src", "dst")).add(np.array([[0.0, 1.0], [2.0, 0.0]]))
    b.counter("only_b").inc(7.0)
    merged = MetricsRegistry.merge([a.snapshot(), b.snapshot()])
    assert merged["wan"]["src=0,dst=1"]["value"] == 4.0
    assert merged["wan"]["src=1,dst=0"]["value"] == 2.0
    # names present in only one snapshot carry through unchanged
    assert merged["only_b"]["-"]["value"] == 7.0
    # merging must not mutate its inputs
    assert a.snapshot()["wan"]["src=0,dst=1"]["value"] == 3.0
    c = MetricsRegistry(enabled=True)
    c.gauge("only_b").set(1.0)  # same (name, tags) cell, different type
    with pytest.raises(ValueError):
        MetricsRegistry.merge([b.snapshot(), c.snapshot()])


def test_counter_keyed_matches_tagged():
    reg = MetricsRegistry(enabled=True)
    key = (("layer", "2"),)
    reg.counter_keyed("hits", key).inc(3.0)
    # the hot-path keyed accessor and the kwargs accessor share the store
    assert reg.counter("hits", layer=2).value == 3.0


def test_matrix_counter_grid_expands_like_tagged_counters():
    reg = MetricsRegistry(enabled=True)
    grid = reg.counter_grid("wan_bytes", axes=("src", "dst"))
    grid.add(np.array([[0.0, 10.0], [0.0, 0.0]]))
    grid.add(np.array([[0.0, 5.0, 0.0], [0.0, 0.0, 2.0], [1.0, 0.0, 0.0]]))
    snap = reg.snapshot()["wan_bytes"]
    # auto-grown shape, nonzero cells only, per-cell counter entries
    assert snap == {
        "src=0,dst=1": {"type": "counter", "value": 15.0},
        "src=1,dst=2": {"type": "counter", "value": 2.0},
        "src=2,dst=0": {"type": "counter", "value": 1.0},
    }
    reg.reset()
    assert reg.snapshot().get("wan_bytes", {}) == {}


def test_disabled_registry_is_shared_noop():
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("a") is _NOOP
    assert reg.gauge("b") is _NOOP
    assert reg.histogram("c") is _NOOP
    assert reg.counter_grid("d", axes=("i", "j")) is _NOOP
    _NOOP.inc()
    _NOOP.set(3.0)
    _NOOP.observe(1.0)
    _NOOP.observe_many([1.0, 2.0])
    _NOOP.add(np.ones((2, 2)))
    assert reg.snapshot() == {}  # nothing was ever stored
    reg.enable()
    assert reg.counter("a") is not _NOOP


def test_to_json_round_trips(tmp_path):
    reg = MetricsRegistry(enabled=True)
    reg.counter("x").inc(2.0)
    path = tmp_path / "metrics.json"
    text = reg.to_json(str(path))
    assert json.loads(path.read_text()) == json.loads(text)
    assert json.loads(text)["x"]["-"]["value"] == 2.0


# -------------------------------------------------------------- histograms
def test_p2_exact_below_five_samples():
    sk = P2Quantile(0.5)
    for v in [3.0, 1.0, 2.0]:
        sk.add(v)
    assert sk.value() == 2.0  # exact small-sample median


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_histogram_scalar_accuracy_vs_numpy(q):
    rng = np.random.default_rng(17)
    data = rng.normal(10.0, 2.0, 20_000)
    h = Histogram("lat", quantiles=(q,))
    for v in data:
        h.observe(v)
    true = float(np.quantile(data, q))
    assert abs(h.quantile(q) - true) < 0.05  # P² on N(10, 2): tight
    assert h.count == len(data)
    assert h.sum == pytest.approx(data.sum())
    assert h.min == data.min() and h.max == data.max()


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_histogram_batched_accuracy_vs_numpy(q):
    """observe_many (batch-P²: sorted-batch seeding + rank-count advance)
    must track numpy as closely as the scalar path."""
    rng = np.random.default_rng(23)
    data = rng.normal(10.0, 2.0, 20_000)
    h = Histogram("lat", quantiles=(q,))
    for chunk in np.array_split(data, 80):  # 250-value batches
        h.observe_many(chunk)
    true = float(np.quantile(data, q))
    assert abs(h.quantile(q) - true) < 0.05
    assert h.count == len(data)
    assert h.sum == pytest.approx(data.sum())
    assert h.min == data.min() and h.max == data.max()


def test_histogram_batched_tied_stream():
    """Serving latencies are heavily tied (RTT-quantized).  The capped
    settle pass must still land inside the tie neighbourhood."""
    rng = np.random.default_rng(5)
    rtts = np.array([0.0, 0.04, 0.08, 0.12, 0.226])
    data = rtts[rng.integers(0, 5, 8_000)] + 0.0  # ~5 distinct values
    h = Histogram("lat", quantiles=(0.5, 0.99))
    for chunk in np.array_split(data, 32):
        h.observe_many(np.sort(chunk))
    # estimates must sit within the discrete support's neighbouring levels
    assert abs(h.quantile(0.5) - np.quantile(data, 0.5)) <= 0.05
    assert abs(h.quantile(0.99) - np.quantile(data, 0.99)) <= 0.05


def test_observe_many_small_batches_fall_back_to_scalar():
    h1 = Histogram("a", quantiles=(0.5,))
    h2 = Histogram("b", quantiles=(0.5,))
    vals = [5.0, 1.0, 3.0]
    for v in vals:
        h1.observe(v)
    h2.observe_many(sorted(vals))  # < 5 samples: exact path either way
    assert h1.quantile(0.5) == h2.quantile(0.5) == 3.0
    h = Histogram("c")
    h.observe_many([])  # empty batch is a no-op
    assert h.count == 0


# ----------------------------------------------------------------- tracing
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0  # each clock read advances one tick
        return self.t


def test_span_nesting_and_parenting():
    tr = Tracer(clock=_FakeClock(), enabled=True)
    with tr.span("outer", track="store", batch=7) as outer:
        with tr.span("inner", track="store") as inner:
            assert inner.parent == outer.sid
        with tr.span("inner2", track="store") as inner2:
            assert inner2.parent == outer.sid
    assert outer.parent is None
    recs = {r.name: r for r in tr.records}
    assert recs["inner"].parent == recs["outer"].sid
    assert recs["outer"].tags == {"batch": 7}
    # inner closed before outer: t0/t1 nest strictly under the fake clock
    assert recs["outer"].t0 < recs["inner"].t0 < recs["inner"].t1 < recs["outer"].t1
    assert recs["outer"].dur_s > 0


def test_record_explicit_parenting():
    tr = Tracer(enabled=True)
    root = tr.record("request", 0.0, 5.0, track="requests", origin=2)
    child = tr.record("queue", 0.0, 1.0, track="requests", parent=root)
    assert root is not None and child == root + 1
    by_sid = {r.sid: r for r in tr.records}
    assert by_sid[child].parent == root
    assert by_sid[root].tags == {"origin": 2}


def test_disabled_tracer_noop_span_still_measures():
    clk = _FakeClock()
    tr = Tracer(clock=clk, enabled=False)
    with tr.span("work", track="store") as sp:
        mid = sp.elapsed_s()
    assert len(tr.records) == 0  # nothing retained...
    assert mid > 0 and sp.end() > 0  # ...but elapsed time is real
    assert sp.end() == sp.end()  # end() idempotent


def test_tracer_follows_default_registry_when_unforced():
    tr = Tracer()  # enabled=None: follows the process-default registry
    old = set_default_registry(MetricsRegistry(enabled=True))
    try:
        assert tr.enabled
        with tr.span("s", track="t"):
            pass
        assert len(tr.records) == 1
    finally:
        set_default_registry(old)
    assert not tr.enabled


def test_tracer_reset():
    tr = Tracer(enabled=True)
    tr.record("a", 0.0, 1.0)
    tr.reset()
    assert len(tr) == 0
    assert tr.record("b", 0.0, 1.0) == 0  # sids restart


# --------------------------------------------------------------- exporters
def test_chrome_export_shape_and_lanes(tmp_path):
    tr = Tracer(enabled=True)
    r0 = tr.record("req", 0.0, 2.0, track="requests", origin=1)
    tr.record("queue", 0.0, 1.0, track="requests", parent=r0)
    tr.record("req", 1.0, 3.0, track="requests", origin=2)  # overlaps r0
    tr.record("wave", 0.0, 1.0, track="migration")
    path = tmp_path / "t.trace.json"
    doc = json.loads(export_chrome_trace(tr, str(path)))
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    assert {m["args"]["name"] for m in meta} == {"requests", "migration"}
    # overlapping roots spread across lanes; the child shares its root's lane
    req = [e for e in spans if e["pid"] == next(
        m["pid"] for m in meta if m["args"]["name"] == "requests")]
    lanes = {(e["name"], e["ts"]): e["tid"] for e in req}
    assert lanes[("req", 0.0)] != lanes[("req", 1e6)]
    assert lanes[("queue", 0.0)] == lanes[("req", 0.0)]
    assert all(isinstance(v, str) for e in spans for v in e["args"].values())
    assert path.read_text().rstrip("\n") == json.dumps(
        doc, sort_keys=True, separators=(",", ":"))


def test_text_dashboard_lists_instruments_and_spans():
    reg = MetricsRegistry(enabled=True)
    reg.counter("serving.requests").inc(12)
    reg.histogram("lat", quantiles=(0.5,)).observe_many(np.arange(10.0))
    tr = Tracer(enabled=True)
    tr.record("drain", 0.0, 1.0, track="scheduler")
    dash = text_dashboard(reg, tr)
    assert "serving.requests" in dash and "counter=12" in dash
    assert "p50=" in dash
    assert "scheduler/drain" in dash and "n=1" in dash


# ------------------------------------------------- scheduler integration
def _tiny_store(seed=0, n=160, m=900, n_pats=16):
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    keep = src != dst
    g = Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, 4, n)
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_pats, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4)
    )


def _traced_run(seed=0, n_req=40, deadline_s=0.05):
    store = _tiny_store(seed)
    clock = SimClock()
    tracer = Tracer(clock=clock.now, enabled=True)
    ctl = AdmissionController(
        store, AdmissionConfig(initial_batch=4, max_batch=16),
        clock=clock, tracer=tracer,
    )
    rng = np.random.default_rng(seed + 7)
    pats = [p for p in store.workload.patterns if len(p.items)]
    for i in range(n_req):
        p = pats[int(rng.integers(0, len(pats)))]
        ctl.submit(p.items, origin=int(rng.integers(0, store.env.n_dcs)),
                   deadline_s=deadline_s, at=0.001 * i)
    ctl.run_until_idle()
    return ctl, tracer


def test_sim_clock_trace_export_is_deterministic():
    _, tr_a = _traced_run(seed=3)
    _, tr_b = _traced_run(seed=3)
    a = export_chrome_trace(tr_a)
    b = export_chrome_trace(tr_b)
    assert a == b  # byte-identical: same seed, same simulated timeline
    names = {r.name for r in tr_a.records}
    assert {"request", "queue", "route", "wan_fetch", "drain"} <= names


def test_miss_causes_partition_deadline_misses():
    # a deadline tighter than any WAN RTT forces misses across causes
    ctl, _ = _traced_run(seed=1, n_req=60, deadline_s=0.004)
    m = ctl.metrics()
    assert m["deadline_misses"] > 0
    assert sum(m["misses_by_cause"].values()) == m["deadline_misses"]
    assert set(m["misses_by_cause"]) == {"queue", "service", "straggler"}
    # per-origin p99 covers exactly the origins that completed requests
    assert set(m["p99_by_origin"]) == set(m["served_by_origin"])
    for p99 in m["p99_by_origin"].values():
        assert p99 >= 0.0


# ------------------------------------------------------ store span sourcing
def test_store_report_times_sourced_from_spans():
    store = _tiny_store(seed=9)
    store._delta_graph = DeltaGraph(store.g)
    old = set_default_registry(MetricsRegistry(enabled=True))
    try:
        store.tracer.reset()
        rng = np.random.default_rng(11)
        report = store.apply_updates(
            random_churn_batch(store._delta_graph, 0.02, rng)
        )
    finally:
        set_default_registry(old)
    recs = [r for r in store.tracer.records if r.name == "store.apply_updates"]
    assert len(recs) == 1
    # the public report field is the root span's elapsed time (read just
    # before the span closes), not a hand-threaded perf_counter delta — so
    # it must sit within the recorded span, a sliver under its duration
    assert 0.0 < report.apply_time_s <= recs[0].dur_s
    assert report.apply_time_s == pytest.approx(recs[0].dur_s, rel=0.05)
