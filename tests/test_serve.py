import numpy as np
import jax

from repro.models.transformer import LMConfig, init_params
from repro.serve.engine import Engine, Request, ServeConfig

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
               d_ff=64, vocab_size=128, remat=False)


def test_engine_completes_all():
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = Engine(params, CFG, ServeConfig(n_slots=3, max_len=64))
    rng = np.random.default_rng(0)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 128, 5), max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 6 for r in done)


def test_continuous_batching_slot_reuse():
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = Engine(params, CFG, ServeConfig(n_slots=2, max_len=64))
    rng = np.random.default_rng(1)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 128, 4), max_new_tokens=3))
    # step until first finishes; new request must be admitted into freed slot
    done = []
    for _ in range(40):
        done += eng.step()
        if len(done) >= 4:
            break
    assert len(done) == 4


def test_greedy_determinism():
    params = init_params(jax.random.PRNGKey(0), CFG)
    outs = []
    for _ in range(2):
        eng = Engine(params, CFG, ServeConfig(n_slots=1, max_len=64))
        eng.submit(Request(rid=0, prompt=np.arange(5), max_new_tokens=8))
        done = eng.run_to_completion()
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1]
