"""Demand plane: single-ownership invariant, forecasters, differentials.

Three bars from the demand-plane PR:

* **Single ownership** — the ``[D, n_items]`` heat table lives in one
  :class:`~repro.demand.ODDemandLayer`; every per-DC ``HeatCache`` row is a
  shared-storage view and the serving path deposits each request exactly
  once (no double-bookkeeping to drift).
* **Forecaster quality** — EWMA tracks a noisy level within a bound; the
  seasonal decomposition beats EWMA one-step-ahead on a seeded diurnal
  series (the follow-the-sun shape pre-staging relies on).
* **Behavior preservation** — predictive mode with a
  :class:`~repro.demand.ZeroForecaster` is replica-set- and route-identical
  to the reactive policy, and a flush with explicitly injected heat equals
  the default flush move-for-move.
"""

import numpy as np
import pytest

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph, diurnal_demand_trace
from repro.demand import (
    DemandView,
    EWMAForecaster,
    ODDemandLayer,
    PersistenceForecaster,
    SeasonalForecaster,
    ZeroForecaster,
)
from repro.serve import (
    AdmissionConfig,
    AdmissionController,
    MaintenanceConfig,
    MaintenancePolicy,
    StoreClient,
)


def _fresh_store(seed=0, n_vertices=400, n_patterns=24, window_s=6.0):
    g = community_graph(
        n_vertices, n_communities=8, p_in=0.04, p_out=0.001, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g, env, wl,
        config=PlacementConfig(precache=False, dhd_steps=4),
        demand_window_s=window_s,
    )


# --------------------------------------------------------- single ownership
def test_cache_heat_is_demand_plane_view(small_store):
    """Every HeatCache row must be a view of the one [D, I] heat table."""
    store = small_store
    for d, cache in store.caches.items():
        assert cache.heat.base is store.demand.heat
        # in-place mutation writes through — same storage, not a copy
        before = store.demand.heat[d, 0]
        # deliberate view write: this test *is* the aliasing invariant check
        cache.heat[0] += 1.0  # geolint: allow[GL003]
        assert store.demand.heat[d, 0] == before + 1.0
        cache.heat[0] -= 1.0  # geolint: allow[GL003]


def test_serve_batch_deposits_heat_exactly_once():
    store = _fresh_store(seed=2)
    pats = [p for p in store.workload.patterns if len(p.items)]
    p = pats[0]
    total0 = float(store.demand.heat.sum())
    store.serve_batch([(p.items, 1), (p.items, 1), (p.items, 3)])
    # each request deposits freq=1.0 per item id into its origin row only:
    # three requests over len(p.items) ids => exactly 3*len heat, not 3*len
    # per cache (the pre-demand-plane double-book)
    assert float(store.demand.heat.sum()) - total0 == pytest.approx(
        3.0 * len(p.items)
    )
    assert float(store.demand.heat[1].sum()) == pytest.approx(2.0 * len(p.items))
    assert float(store.demand.heat[3].sum()) == pytest.approx(1.0 * len(p.items))
    assert float(store.demand.heat[[0, 2, 4]].sum()) == 0.0
    # the od ground-truth table saw the same mass (monotone, never decayed)
    assert float(store.demand.od.sum()) == pytest.approx(3.0 * len(p.items))


def test_observe_accumulates_duplicate_ids():
    layer = ODDemandLayer(8, 2)
    layer.observe(np.array([3, 3, 5]), origin=1)
    assert layer.heat[1, 3] == 2.0
    assert layer.heat[1, 5] == 1.0


# ------------------------------------------------------------------ windows
def test_windowing_rates_and_rate_floor():
    layer = ODDemandLayer(4, 2, window_s=10.0, rate_alpha=0.5, rate_floor=0.05)
    layer.observe(np.array([0, 1]), origin=0, freq=100.0)
    assert layer.advance_to(10.0) == 1
    assert layer.window_index == 1
    assert layer.rate[0, 0] == pytest.approx(0.5 * 100.0 / 10.0)
    # origin 0 goes quiet while origin 1 stays busy (the follow-the-sun
    # shape): origin 0's EWMA tail decays below rate_floor x the refreshed
    # global max and is clamped to exact zero (drop-eligibility)
    for k in range(2, 9):
        layer.observe(np.array([2]), origin=1, freq=100.0)
        assert layer.advance_to(10.0 * k) == 1
    assert layer.rate[0, 0] == 0.0
    assert layer.rate[1, 2] > 0.0
    assert len(layer.history) == 8


def test_bulk_skip_matches_incremental_decay():
    a = ODDemandLayer(4, 1, window_s=1.0, rate_alpha=0.35)
    b = ODDemandLayer(4, 1, window_s=1.0, rate_alpha=0.35)
    for layer in (a, b):
        layer.observe(np.array([0]), freq=7.0)
    for k in range(1, 7):
        a.advance_to(float(k))
    b.advance_to(6.0)  # one jump over the same idle stretch
    assert a.window_index == b.window_index == 6
    np.testing.assert_allclose(a.rate, b.rate, rtol=1e-6)


def test_forecast_error_settles_on_window_close():
    layer = ODDemandLayer(4, 2, window_s=1.0)
    layer.observe(np.array([0]), origin=0, freq=5.0)
    layer.advance_to(1.0)
    layer.forecast(PersistenceForecaster(), horizon=1)
    assert layer.stats()["pending_forecasts"] == 1
    layer.observe(np.array([0]), origin=0, freq=5.0)
    layer.advance_to(2.0)
    assert layer.stats()["pending_forecasts"] == 0
    assert layer.last_forecast_abs_err is not None
    # persistence predicted window 1's intensity = window 0's = 5.0; realized
    # is also 5.0, so the settled error is ~zero at origin 0
    assert layer.last_forecast_abs_err[0] == pytest.approx(0.0, abs=1e-9)


# -------------------------------------------------------------- forecasters
def test_ewma_tracks_noisy_level():
    rng = np.random.default_rng(0)
    series = 10.0 + rng.normal(0.0, 0.5, size=64)
    hat = EWMAForecaster(alpha=0.4).forecast(series, 1)
    assert abs(hat - 10.0) < 1.0


def test_seasonal_beats_ewma_on_diurnal_series():
    period = 8
    rng = np.random.default_rng(1)
    t = np.arange(6 * period)
    # multiplicative diurnal shape: level x von-Mises-ish bump, mild noise
    shape = np.exp(2.0 * (np.cos(2 * np.pi * t / period) - 1.0))
    series = 20.0 * shape * (1.0 + rng.normal(0.0, 0.05, size=len(t)))
    models = {
        "ewma": EWMAForecaster(),
        "seasonal": SeasonalForecaster(period=period),
    }
    mae = {}
    for name, m in models.items():
        errs = [
            abs(m.forecast(series[:k], 1) - series[k])
            for k in range(2 * period, len(t))
        ]
        mae[name] = float(np.mean(errs))
    assert mae["seasonal"] < 0.5 * mae["ewma"], mae
    # and the seasonal MAE is tight in absolute terms vs the series scale
    assert mae["seasonal"] < 0.15 * float(series.max())


def test_forecaster_edge_cases():
    empty = np.zeros(0)
    assert ZeroForecaster().forecast(np.array([5.0, 7.0]), 1) == 0.0
    assert PersistenceForecaster().forecast(empty, 1) == 0.0
    assert PersistenceForecaster().forecast(np.array([1.0, 3.0]), 1) == 3.0
    assert EWMAForecaster().forecast(empty, 1) == 0.0
    assert SeasonalForecaster(period=4).forecast(empty, 1) == 0.0
    with pytest.raises(ValueError):
        SeasonalForecaster(period=0)
    with pytest.raises(ValueError):
        EWMAForecaster(alpha=0.0)


def test_forecast_view_spreads_intensity_via_profile():
    layer = ODDemandLayer(6, 2, window_s=1.0)
    layer.observe(np.array([0, 1]), origin=0, freq=10.0)
    layer.advance_to(1.0)
    view = layer.forecast(PersistenceForecaster(), horizon=1)
    assert isinstance(view, DemandView)
    assert view.horizon == 1
    assert view.read_rates.shape == (6, 2)
    # origin 0's forecast mass lands only on the items it actually read
    assert view.read_rates[0, 0] > 0 and view.read_rates[1, 0] > 0
    assert float(view.read_rates[2:, 0].sum()) == 0.0
    assert float(view.read_rates[:, 1].sum()) == 0.0


# ----------------------------------------------------- id-space re-keying
def test_grow_and_take_rows_keep_alignment():
    layer = ODDemandLayer(5, 2)  # 3 nodes + 2 edges, say
    layer.observe(np.array([0, 4]), origin=1)
    layer.grow_items(old_n_nodes=3, n_new_vertices=1, n_new_edges=1)
    # vertex rows stay at [0, 3), old edges shift by the new vertex count
    assert layer.n_items == 7
    assert layer.heat[1, 0] == 1.0
    assert layer.heat[1, 5] == 1.0  # old edge row 4 -> 3 + 1 + (4 - 3) = 5
    keep = np.array([0, 2, 5])
    layer.take_rows(keep)
    assert layer.n_items == 3
    assert layer.heat[1, 0] == 1.0 and layer.heat[1, 2] == 1.0


# ------------------------------------------------------------ differentials
def _run_policy_mode(store, trace, mode, window_s):
    common = dict(
        window_s=2.0,
        budget_frac=0.05,
        flush_every_s=window_s,
        heat_source="measured",
        plan_kw=dict(theta_add=0.3, theta_drop=0.25),
    )
    if mode == "reactive":
        cfg = MaintenanceConfig(**common)
    else:
        cfg = MaintenanceConfig(
            predictive=True, forecaster=ZeroForecaster(),
            prestage_horizon=1, prestage_theta_add=0.3, **common,
        )
    policy = MaintenancePolicy(store, cfg)
    ctl = AdmissionController(
        store,
        AdmissionConfig(policy="greedy", fairness="fifo", max_batch=16),
        policy=policy,
    )
    client = StoreClient(ctl)
    for t, items, origin, prio, deadline in trace:
        client.submit(items, origin, deadline_s=deadline, priority=prio, at=t)
    done = ctl.run_until_idle()
    assert len(done) == len(trace)
    return policy, done


def test_zero_forecast_predictive_identical_to_reactive():
    """The refactor differential: a predictive policy whose forecaster
    predicts zero demand must leave the exact replica sets and routes the
    reactive policy does — pre-staging against nothing changes nothing."""
    period_s, window_s = 24.0, 3.0
    outcomes = {}
    for mode in ("reactive", "zero_predictive"):
        store = _fresh_store(seed=5, window_s=window_s)
        pats = [p for p in store.workload.patterns if len(p.items)]
        trace, _ = diurnal_demand_trace(
            pats, store.env.n_dcs, 400, period_s, n_periods=2,
            locality=1.0, seed=7, deadline_s=0.5,
        )
        policy, done = _run_policy_mode(store, trace, mode, window_s)
        outcomes[mode] = (
            store.state.delta.copy(),
            store.state.route.copy(),
            np.array([h.latency_s for h in done]),
            policy,
        )
    d_r, r_r, lat_r, pol_r = outcomes["reactive"]
    d_z, r_z, lat_z, pol_z = outcomes["zero_predictive"]
    assert np.array_equal(d_r, d_z), "replica sets diverged under zero forecast"
    assert np.array_equal(r_r, r_z), "routes diverged under zero forecast"
    np.testing.assert_allclose(lat_r, lat_z)
    assert pol_z.prestage_hits == 0 and pol_z.prestage_wasted == 0
    # the zero-forecast plans really were empty, not merely rolled back
    assert all(
        len(p.moves) == 0 for p in pol_z.plans if getattr(p, "prestaged", True)
    ) or pol_z.n_waves == pol_r.n_waves


def test_injected_heat_matches_default_flush():
    """plan_flush(item_heat=X) with the default path's own X must produce
    the identical move list — the injection point is behavior-preserving."""
    store = _fresh_store(seed=6)
    plan_default = store.plan_flush(window_s=None)
    # rebuild the exact equilibrium heat the default path used
    vheat = store._heat.vertex_heat
    eheat = 0.5 * (vheat[store.g.src] + vheat[store.g.dst])
    item_heat = np.concatenate([vheat, eheat])
    plan_injected = store.plan_flush(window_s=None, item_heat=item_heat)
    assert [
        (m.item, m.dc, m.kind) for m in plan_default.moves
    ] == [(m.item, m.dc, m.kind) for m in plan_injected.moves]


def test_demand_guard_releases_demand_cold_drops():
    """Regression for the wholesale-rollback bug: a flush planned against
    injected demand tables must be *guarded* against the same tables, so
    replicas with zero live demand are actually dropped (not rolled back
    for regressing SLOs on retired synthetic reads)."""
    store = _fresh_store(seed=8)
    I, D = store.g.n_items, store.env.n_dcs
    # hand-place an extra replica nobody reads from
    item = int(np.argmax(store.g.item_size()))
    prim = np.where(store.state.delta[item])[0][0]
    dc_extra = (prim + 2) % D
    store.state.delta[item, dc_extra] = True
    store._resync_route_index()
    store.route_index.rebuild(store.state.delta)
    store.state.route = store.route_index.nearest
    # demand view: modest uniform heat on a few other items, zero on `item`
    rates = np.zeros((I, D))
    hot = [i for i in range(12) if i != item]
    for i in hot:
        rates[i, (prim + 1) % D] = 5.0
    heat = rates.sum(axis=1)
    plan, applier = store.begin_flush(
        window_s=2.0, item_heat=heat, read_rates=rates,
        theta_add=0.3, theta_drop=0.25,
    )
    assert any(
        m.kind == "drop" and m.item == item and m.dc == dc_extra
        for m in plan.moves
    ), "demand-cold replica not planned for drop"
    while applier.peek() is not None:
        applier.apply_next()
    applier.finish()
    assert plan.rolled_back == 0, "guard rolled back demand-cold drops"
    assert not store.state.delta[item, dc_extra]


def test_static_guard_unchanged_by_demand_gating():
    """With the offline workload's own r_xy, the demand gating in
    check_constraints is a no-op: r_xy is built from the patterns' r_py, so
    every (pattern, origin) pair with r_py > 0 still binds."""
    from repro.core.cost import check_constraints

    store = _fresh_store(seed=9)
    flags = check_constraints(
        store.workload.patterns, store.state, store.workload.r_xy,
        store.g.item_size(), store.env, store.config.gamma_max_s,
    )
    for p in store.workload.patterns:
        if not len(p.items):
            continue
        for y in np.where(p.r_py > 0)[0]:
            assert (store.workload.r_xy[p.items, y] > 0).any()
    assert set(flags) == {
        "a_route_on_replica", "a_requested_routed",
        "b_pattern_route_on_replica", "c_avg_latency", "d_pattern_slo",
        "e_binary",
    }
