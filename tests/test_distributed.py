import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.distributed import compression, fault, geo_sharding
from repro.data.synthetic import make_benchmark_graph


def test_int8_roundtrip_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compression.compress_int8(x)
    back = compression.decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_error_feedback_converges(seed):
    """EF residual makes the *accumulated* compressed signal unbiased."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * 0.1, jnp.float32)
    res = jnp.zeros(256)
    tot_c = jnp.zeros(256)
    steps = 30
    for _ in range(steps):
        c, res = compression.apply_error_feedback(g, res, "int8")
        tot_c = tot_c + c
    err = float(jnp.max(jnp.abs(tot_c - g * steps)))
    # residual is bounded by one quantization step
    assert err < float(jnp.max(jnp.abs(g))) * 0.1 + 1e-3


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out, mask = compression.compress_topk(x, frac=0.4)
    assert bool(mask[1]) and bool(mask[3])
    assert float(out[4]) == 0.0


def test_elastic_mesh_shapes():
    assert fault.elastic_mesh_shape(256) == ((16, 16), ("data", "model"))
    shape, axes = fault.elastic_mesh_shape(240)  # lost 16 devices
    assert int(np.prod(shape)) == 240
    assert shape[-1] >= 1
    shape, axes = fault.elastic_mesh_shape(512, multi_pod=True)
    assert shape == (2, 16, 16)


def test_failure_simulator():
    sim = fault.FailureSimulator([(5, 2)])
    assert sim.check(4) is None
    ev = sim.check(5)
    assert ev is not None and ev.n_failed == 2


def test_straggler_mitigation():
    m = fault.StragglerMitigator(4)
    for s, t in [(0, 1.0), (1, 1.1), (2, 1.0), (3, 5.0)]:
        m.observe(s, t)
    plan = m.plan()
    assert 3 in plan  # the slow shard reassigned
    assert plan[3] in (0, 2)


def test_straggler_detector_flags_relative_lag():
    det = fault.StragglerDetector(4, threshold=1.8, alpha=0.5)
    # one observed shard has no fleet to lag behind
    det.observe(0, 1.0)
    assert not det.is_straggler(0) and det.flagged() == []
    for s, t in [(1, 1.0), (2, 1.1), (3, 1.0)]:
        det.observe(s, t)
    assert det.flagged() == []
    # EWMA must converge past the threshold, not flag one spike
    det.observe(3, 4.0)  # ewma: 2.5x median -> flagged
    assert det.is_straggler(3)
    assert det.flagged() == [3]
    assert not det.is_straggler(0) and not det.is_straggler(1)
    snap = det.snapshot()
    assert snap["flagged"] == [3]
    assert snap["median_s"] == det.median()
    # recovery: fast observations pull the EWMA back under the bar
    for _ in range(8):
        det.observe(3, 1.0)
    assert not det.is_straggler(3)
    # out-of-range shards never flag
    assert not det.is_straggler(-1) and not det.is_straggler(99)


def test_mesh_env_layered_graph():
    """The mesh-level GeoEnvironment yields exactly 2 latency layers
    (ICI, DCN) when pods are present — the paper's structure at pod scale."""
    from repro.core.layered_graph import build_layered_graph
    from repro.core.graph import Graph

    env = geo_sharding.mesh_env(8, shards_per_pod=4)
    rng = np.random.default_rng(0)
    src = rng.integers(0, 64, 200)
    dst = rng.integers(0, 64, 200)
    keep = src != dst
    g = Graph.from_edges(64, src[keep], dst[keep], partition=np.arange(64) % 8)
    lg = build_layered_graph(g, env, thresholds_s=[1e-5])
    assert lg.n_layers == 2
    # layer-1 edges connect same-pod shards, layer-2 cross-pod
    for b in lg.layers[1]:
        dcs = b.dcs
        assert len(set(d // 4 for d in dcs)) == 1


def test_halo_plan_resolves_cut_edges():
    g = make_benchmark_graph("wiki", n_dcs=4, seed=2)
    heat = np.random.default_rng(0).random(g.n_nodes) + 0.5
    plan = geo_sharding.plan_gnn_halo(g, 4, vertex_heat=heat, n_layers=15)
    assert plan.cut_edges_before > 0
    assert 0 < plan.resolve_frac <= 1.0
    # halo vertices are remote to their shard
    for s, h in enumerate(plan.halo):
        if len(h):
            assert (g.partition[h] != s).all()


def test_expert_and_row_replicas():
    load = np.array([0.5, 0.2, 0.1, 0.1, 0.05, 0.05, 0.0, 0.0])
    f = geo_sharding.plan_expert_replicas(load, 16)
    assert f[0] == 4 and f[-1] == 1  # hot expert replicated, capped
    rows = geo_sharding.plan_row_replicas(
        np.concatenate([np.zeros(990), np.full(10, 100.0)]), quantile=0.5
    )
    assert set(rows) == set(range(990, 1000))
