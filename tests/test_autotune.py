"""Kernel autotuner: winner caching, deterministic serialization, fallbacks."""
import json


from repro.kernels import ops
from repro.kernels.autotune import (
    TABLE_VERSION,
    Autotuner,
    shape_bucket,
    signature_key,
)
from repro.obs import MetricsRegistry


def test_shape_bucket_pow2():
    assert shape_bucket(1) == 8  # floor
    assert shape_bucket(8) == 8
    assert shape_bucket(9) == 16
    assert shape_bucket(1000) == 1024
    assert shape_bucket(5, floor=2) == 8  # still pow2 above n


def test_signature_key_stable():
    assert signature_key((128, 1024, 5, 3)) == "128x1024x5x3"


def _tuner():
    return Autotuner(registry=MetricsRegistry(enabled=True))


def test_sweep_caches_winner_and_lookup_hits():
    tuner = _tuner()
    calls = []

    def runner(cfg):
        calls.append(cfg["impl"])

    win = tuner.sweep(
        "route_expand", (8, 64, 5, 3),
        [{"impl": "ref"}, {"impl": "subsets"}],
        runner, repeats=2, device="cpu:test",
    )
    assert win["impl"] in ("ref", "subsets")
    # warm-up + repeats per candidate
    assert len(calls) == 2 * 3
    got = tuner.lookup("route_expand", (8, 64, 5, 3), device="cpu:test")
    assert got == win
    reg = tuner._reg()
    assert reg.counter("kernels.autotune_hit", op="route_expand").value == 1


def test_unknown_device_lookup_misses_with_counter():
    tuner = _tuner()
    assert tuner.lookup("route_expand", (8, 64, 5, 3), device="tpu:v99") is None
    reg = tuner._reg()
    assert reg.counter("kernels.autotune_miss", op="route_expand").value == 1


def test_dumps_sorted_key_deterministic():
    """Two tables built with insertions in different orders serialize to
    byte-identical JSON (sorted keys + version stamp)."""
    def fill(order):
        t = _tuner()
        for sig in order:
            t._table.setdefault("cpu:x", {}).setdefault("op", {})[
                signature_key(sig)
            ] = {"config": {"impl": "ref"}, "best_s": 0.5, "timings": []}
        return t.dumps()

    a = fill([(8, 64), (16, 128), (8, 256)])
    b = fill([(8, 256), (8, 64), (16, 128)])
    assert a == b
    assert json.loads(a)["version"] == TABLE_VERSION


def test_save_load_round_trip(tmp_path):
    tuner = _tuner()
    tuner.sweep(
        "route_expand", (8, 64, 5, 3), [{"impl": "ref"}],
        lambda cfg: None, device="cpu:test",
    )
    path = tmp_path / "autotune.json"
    tuner.save(str(path))
    fresh = _tuner()
    assert fresh.load(str(path)) is True
    assert fresh.lookup("route_expand", (8, 64, 5, 3), device="cpu:test") == {
        "impl": "ref"
    }
    # round trip is byte-stable
    fresh.save(str(tmp_path / "again.json"))
    assert path.read_text() == (tmp_path / "again.json").read_text()


def test_load_rejects_stale_version():
    tuner = _tuner()
    ok = tuner.load({"version": TABLE_VERSION + 1, "tables": {"cpu:x": {}}})
    assert ok is False
    reg = tuner._reg()
    assert reg.counter("kernels.autotune_stale_table").value == 1
    assert tuner.snapshot()["tables"] == {}


def test_reset_drops_winners():
    tuner = _tuner()
    tuner.sweep(
        "route_expand", (8, 64, 5, 3), [{"impl": "ref"}],
        lambda cfg: None, device="cpu:test",
    )
    tuner.reset()
    assert tuner.lookup("route_expand", (8, 64, 5, 3), device="cpu:test") is None


def test_tie_break_on_config_json():
    """Under equal timings the winner is the lexicographically smallest
    sorted-key config JSON — deterministic across runs."""
    tuner = _tuner()
    fake = iter([0.5] * 100)

    import repro.kernels.autotune as at

    real = at.time.perf_counter
    at.time.perf_counter = lambda: next(fake, 50.0)
    try:
        win = tuner.sweep(
            "op", (8,),
            [{"impl": "zeta"}, {"impl": "alpha"}],
            lambda cfg: None, repeats=1, device="cpu:test",
        )
    finally:
        at.time.perf_counter = real
    assert win == {"impl": "alpha"}


def test_route_expand_candidates_by_backend():
    cpu = ops.route_expand_candidates("cpu", n_dcs=5)
    assert {"impl": "ref"} in cpu
    assert {"impl": "subsets"} in cpu
    # too many DCs for the 2**D histogram: subsets is withdrawn
    wide = ops.route_expand_candidates("cpu", n_dcs=16)
    assert all(c["impl"] != "subsets" for c in wide)
    tpu = ops.route_expand_candidates("tpu", n_dcs=5)
    assert any(c["impl"] == "kernel" for c in tpu)
    assert all(c["impl"] != "subsets" for c in tpu)
