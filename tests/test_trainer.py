import numpy as np
import jax

from repro.data.pipeline import TokenPipeline
from repro.distributed.fault import FailureSimulator
from repro.models.transformer import LMConfig, init_params, train_loss
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
               d_ff=64, vocab_size=128, remat=False)


def _trainer(tmp, steps=10, **kw):
    params = init_params(jax.random.PRNGKey(0), CFG)
    tcfg = TrainerConfig(
        total_steps=steps, ckpt_every=4, ckpt_dir=str(tmp),
        opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps), **kw,
    )
    return Trainer(lambda p, b: train_loss(p, b, CFG), params, tcfg)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=12)
    m = tr.run(iter(TokenPipeline(128, 8, 16)))
    assert np.mean(m["loss"][-3:]) < np.mean(m["loss"][:3])


def test_resume_continues(tmp_path):
    tr = _trainer(tmp_path, steps=8)
    tr.run(iter(TokenPipeline(128, 8, 16)))
    tr2 = _trainer(tmp_path, steps=12)
    m2 = tr2.run(iter(TokenPipeline(128, 8, 16)))
    assert len(m2["loss"]) == 4  # resumed at 8, ran 4 more


def test_failure_recovery(tmp_path):
    params = init_params(jax.random.PRNGKey(0), CFG)
    tcfg = TrainerConfig(total_steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                         opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    tr = Trainer(lambda p, b: train_loss(p, b, CFG), params, tcfg,
                 failure_sim=FailureSimulator([(7, 1)]))
    m = tr.run(iter(TokenPipeline(128, 8, 16)))
    assert len(m["recoveries"]) == 1
    assert m["recoveries"][0]["restored_step"] == 6


def test_microbatch_equivalence(tmp_path):
    """Accumulated microbatch grads ~= full-batch step (same data)."""
    m1 = _trainer(tmp_path / "a", steps=3, microbatch=1).run(iter(TokenPipeline(128, 8, 16)))
    m2 = _trainer(tmp_path / "b", steps=3, microbatch=2).run(iter(TokenPipeline(128, 8, 16)))
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=2e-2)
