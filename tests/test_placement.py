import numpy as np
import pytest

from repro.core.cost import total_cost
from repro.core.layered_graph import build_layered_graph
from repro.core.placement import (
    PlacedUnit,
    PlacementConfig,
    overlap_centric_placement,
    replication_gain,
)


def test_placement_places_primaries(small_setup):
    g, env, csr, wl, pats = small_setup
    lg = build_layered_graph(g, env)
    state, stats = overlap_centric_placement(
        lg, wl, PlacementConfig(precache=False, dhd_steps=4)
    )
    # primary copies always present
    assert state.delta[np.arange(g.n_nodes), g.partition].all()
    # every accessed item has at least one replica and a route
    accessed = np.where(wl.r_xy.sum(1) > 0)[0]
    assert state.delta[accessed].any(axis=1).all()
    assert (state.route[accessed] >= 0).all()


def test_placement_reduces_cost_vs_primary_only(small_setup):
    g, env, csr, wl, pats = small_setup
    lg = build_layered_graph(g, env)
    state, _ = overlap_centric_placement(
        lg, wl, PlacementConfig(precache=False, dhd_steps=4)
    )
    from repro.core.cost import PlacementState

    base = PlacementState.empty(g.n_items, env.n_dcs)
    base.delta[np.arange(g.n_nodes), g.partition] = True
    base.delta[g.n_nodes + np.arange(g.n_edges), g.partition[g.src]] = True
    base.route_nearest(env)
    sizes = g.item_size()
    c_placed = total_cost(pats, state, wl.r_xy, wl.w_xy, sizes, env).total
    c_base = total_cost(pats, base, wl.r_xy, wl.w_xy, sizes, env).total
    assert c_placed < c_base


def test_replication_gain_signs(paper_env):
    env = paper_env
    sizes = np.ones(20, np.float32)
    hot = PlacedUnit(np.arange(5), r_py=np.array([0, 1000.0, 0, 0, 0]),
                     w_py=np.zeros(5), eta=1.0, key=(0,))
    cold = PlacedUnit(np.arange(5), r_py=np.array([0, 1e-9, 0, 0, 0]),
                      w_py=np.full(5, 10.0), eta=1.0, key=(1,))
    holder = np.array([0, 1])
    children = [np.array([1])]
    assert replication_gain(hot, holder, children, sizes, env) > 0
    assert replication_gain(cold, holder, children, sizes, env) < 0


def test_eviction_cools_unused(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    cache = store.caches[0]
    before = cache.cached_mask().sum()
    if before == 0:
        pytest.skip("no cached replicas at DC0")
    # no accesses, several decay rounds -> evictions happen
    cache.step(n_steps=8)
    evicted = cache.evict()
    assert len(evicted) >= 0
    assert not store.state.delta[evicted, 0].any()
    # refresh routes (Alg. 3 line 10) — the session store is shared
    store.state.route_nearest(env)
