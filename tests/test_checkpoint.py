
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 4)), "b": jnp.zeros(3)},
        "opt": {"mu": {"w": jnp.ones((4, 4))}, "step": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    mgr.save(10, state)
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, jax.tree_util.tree_map(np.zeros_like, state))
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_atomicity_torn_manifest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _state())
    mgr.save(10, _state(1))
    # corrupt the newest manifest -> restore falls back to step 5
    with open(tmp_path / "step_00000010" / "MANIFEST.json", "w") as f:
        f.write("{not json")
    assert mgr.latest_step() == 5


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _state())
    assert mgr.all_steps() == [3, 4]


def test_config_hash_guard(tmp_path):
    mgr = CheckpointManager(str(tmp_path), config_hash="aaa", async_save=False)
    mgr.save(1, _state())
    mgr2 = CheckpointManager(str(tmp_path), config_hash="bbb")
    with pytest.raises(ValueError):
        mgr2.restore(1, _state())


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(3, _state())
    mgr.wait()
    assert mgr.latest_step() == 3
