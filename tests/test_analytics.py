import jax.numpy as jnp
import networkx as nx
import numpy as np

from repro.core import analytics


def _rand_graph(seed=0, n=40, m=120):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    keep = src != dst
    return n, src[keep], dst[keep]


def test_pagerank_sums_to_one():
    n, src, dst = _rand_graph()
    # add self loop for dangling nodes handled by damping; check mass ~1
    r = analytics.pagerank(jnp.asarray(src), jnp.asarray(dst), n, 30)
    assert 0.5 < float(r.sum()) <= 1.01  # dangling mass leaks, bounded


def test_sssp_matches_networkx():
    n, src, dst = _rand_graph(3)
    w = np.random.default_rng(1).random(len(src)).astype(np.float32) + 0.1
    d = analytics.sssp(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), 0, n, n_iters=n)
    gx = nx.DiGraph()
    gx.add_nodes_from(range(n))
    for s, t, ww in zip(src, dst, w):
        if gx.has_edge(int(s), int(t)):
            gx[int(s)][int(t)]["weight"] = min(gx[int(s)][int(t)]["weight"], float(ww))
        else:
            gx.add_edge(int(s), int(t), weight=float(ww))
    ref = nx.single_source_dijkstra_path_length(gx, 0)
    for v in range(n):
        expect = ref.get(v, np.inf)
        np.testing.assert_allclose(float(d[v]), expect, rtol=1e-4, atol=1e-5)


def test_kcore_matches_networkx():
    n, src, dst = _rand_graph(5)
    core, rounds = analytics.core_decomposition(n, src, dst)
    gx = nx.Graph()
    gx.add_nodes_from(range(n))
    gx.add_edges_from(zip(src.tolist(), dst.tolist()))
    gx.remove_edges_from(nx.selfloop_edges(gx))
    ref = nx.core_number(gx)
    for v in range(n):
        assert core[v] == ref[v], (v, core[v], ref[v])
    assert rounds >= 1


def test_lpa_converges_to_components():
    # two disjoint cliques -> two labels
    src = np.array([0, 1, 2, 4, 5, 6], dtype=np.int32)
    dst = np.array([1, 2, 0, 5, 6, 4], dtype=np.int32)
    lab = analytics.label_propagation(jnp.asarray(src), jnp.asarray(dst), 8, 10)
    lab = np.asarray(lab)
    assert lab[0] == lab[1] == lab[2]
    assert lab[4] == lab[5] == lab[6]
    assert lab[0] != lab[4]


def test_simulate_execution_sites(small_setup):
    g, env, csr, wl, pats = small_setup
    site = g.partition.astype(np.int64)
    ex = analytics.simulate_execution(env, g, site, n_iters=10)
    assert ex.time_s > 0 and ex.wan_bytes >= 0
    # single site -> zero WAN
    ex1 = analytics.simulate_execution(env, g, np.zeros(g.n_nodes, np.int64), 10)
    assert ex1.wan_bytes == 0 and ex1.cut_edges == 0
