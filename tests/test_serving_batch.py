"""Batched online serving: route_online_batch / serve_batch / controller drain.

Correctness bar: the vectorized batch path must match ``route_online``
request-for-request (same served_by, latency, layers, misses).
"""
import math

import numpy as np
import pytest

from repro.core.routing import route_online, route_online_batch
from repro.serve import AdmissionConfig, AdmissionController, StoreClient


def _fifo_stack(store, max_batch):
    """The FIFO drain configuration the deleted GraphFrontend shim used."""
    ctl = AdmissionController(
        store,
        AdmissionConfig(policy="greedy", fairness="fifo", max_batch=max_batch),
    )
    return ctl, StoreClient(ctl)


def _requests(pats, n_dcs, per_pattern_origins=True):
    reqs = []
    for p in pats[:20]:
        if per_pattern_origins:
            for o in range(n_dcs):
                reqs.append((p.items, o))
        else:
            reqs.append((p.items, int(np.argmax(p.r_py))))
    return reqs


def test_batch_matches_single(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    reqs = _requests(pats, env.n_dcs)
    batch = route_online_batch(store.lg, store.state, reqs)
    assert len(batch) == len(reqs)
    for (items, origin), b in zip(reqs, batch):
        s = route_online(store.lg, store.state, items, origin)
        assert np.array_equal(s.served_by, b.served_by)
        assert s.n_missing == b.n_missing
        assert s.layers_used == b.layers_used
        # float32 size sums accumulate in a different order in the batch path
        assert s.latency_s == pytest.approx(b.latency_s, rel=1e-6)
        assert s.per_dc_latency.keys() == b.per_dc_latency.keys()
        for d, lat in s.per_dc_latency.items():
            assert lat == pytest.approx(b.per_dc_latency[d], rel=1e-6)


def test_batch_edge_cases(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    assert route_online_batch(store.lg, store.state, []) == []
    # empty item list resolves trivially
    res = route_online_batch(store.lg, store.state, [(np.zeros(0, np.int64), 0)])
    assert res[0].n_missing == 0 and res[0].latency_s == 0.0
    # unroutable item (no replica anywhere) is reported missing, not served
    ghost = store.state.delta.any(axis=1).argmin()
    if not store.state.delta[ghost].any():
        res = route_online_batch(
            store.lg, store.state, [(np.asarray([ghost]), 1)]
        )
        assert res[0].n_missing == 1
        assert res[0].served_by[0] == -1


def test_serve_batch_observes_heat(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    origin = int(np.argmax(pats[0].r_py))
    before = store.caches[origin].heat.copy()
    store.serve_batch([(pats[0], origin), (pats[0], origin)])
    gained = store.caches[origin].heat - before
    np.testing.assert_allclose(gained[pats[0].items], 2.0)  # duplicates add


class _FlakyStore:
    """Store stub whose first ``serve_batch`` raises (transient failure)."""

    def __init__(self, store, n_failures=1):
        self.store = store
        self.failures_left = n_failures
        self.calls = 0

    def serve_batch(self, reqs):
        self.calls += 1
        if self.failures_left > 0:
            self.failures_left -= 1
            raise RuntimeError("transient store failure")
        return self.store.serve_batch(reqs)


def test_flush_exception_preserves_queue(small_setup, small_store):
    """Regression: the old drain loop popped a chunk *before* serving it, so
    an exception mid-drain silently lost every in-flight request.  The
    controller requeues the failing batch instead."""
    g, env, csr, wl, pats = small_setup
    flaky = _FlakyStore(small_store)
    ctl, client = _fifo_stack(flaky, max_batch=4)
    rids = [
        client.submit(p.items, int(np.argmax(p.r_py)), deadline_s=math.inf).rid
        for p in pats[:10]
    ]
    with pytest.raises(RuntimeError):
        ctl.run_until_idle()
    # nothing served, nothing lost — the whole queue survives the failure
    assert ctl.pending == 10
    assert ctl.completed == 0
    assert [h.rid for h in ctl.pending_handles()] == rids  # FIFO order intact
    done = ctl.run_until_idle()  # retry drains everything
    out = {h.rid: h.result for h in done}
    assert sorted(out.keys()) == rids
    assert ctl.pending == 0 and ctl.completed == 10
    for p, rid in zip(pats[:10], rids):
        ref = small_store.serve_online(p, int(np.argmax(p.r_py)))
        assert np.array_equal(out[rid].served_by, ref.served_by)


def test_batch1_fast_path_parity(small_setup, small_store):
    """The size-1 chunk fast path must stay request-identical to the scalar
    router (it *is* the scalar router) — all result fields, not just routes."""
    g, env, csr, wl, pats = small_setup
    store = small_store
    for p in pats[:8]:
        for origin in range(env.n_dcs):
            (b,) = route_online_batch(store.lg, store.state, [(p.items, origin)])
            s = route_online(store.lg, store.state, p.items, origin)
            assert np.array_equal(s.served_by, b.served_by)
            assert s.latency_s == b.latency_s
            assert s.per_dc_latency == b.per_dc_latency
            assert s.layers_used == b.layers_used
            assert s.n_missing == b.n_missing


def test_controller_fifo_drain(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    ctl, client = _fifo_stack(store, max_batch=8)
    rids = []
    for p in pats[:30]:
        rids.append(
            client.submit(
                p.items, int(np.argmax(p.r_py)), deadline_s=math.inf
            ).rid
        )
    assert ctl.pending == 30
    done = ctl.run_until_idle()
    out = {h.rid: h.result for h in done}
    assert ctl.pending == 0
    assert ctl.completed == 30
    assert sorted(out.keys()) == rids
    for p, rid in zip(pats[:30], rids):
        ref = store.serve_online(p, int(np.argmax(p.r_py)))
        assert np.array_equal(out[rid].served_by, ref.served_by)


def test_batch_of_one_books_batch_path_telemetry(small_setup, small_store):
    """The size-1 scalar fast path must account exactly like the batch path:
    same counters, same values — duplicating the request into a size-2 batch
    books exactly double (PR 8's batch-1 parity fix)."""
    from repro.obs import MetricsRegistry

    g, env, csr, wl, pats = small_setup
    store = small_store
    req = (pats[0].items, (int(np.argmax(pats[0].r_py)) + 1) % env.n_dcs)

    reg1 = MetricsRegistry(enabled=True)
    route_online_batch(store.lg, store.state, [req], registry=reg1)
    reg2 = MetricsRegistry(enabled=True)
    route_online_batch(store.lg, store.state, [req, req], registry=reg2)

    s1, s2 = reg1.snapshot(), reg2.snapshot()
    assert s1["serving.requests"]["-"]["value"] == 1.0
    assert s2["serving.requests"]["-"]["value"] == 2.0
    for tag, rec in s2.get("routing.layer_hits", {}).items():
        assert s1["routing.layer_hits"][tag]["value"] == rec["value"] / 2.0
    assert set(s1.get("routing.layer_hits", {})) == set(
        s2.get("routing.layer_hits", {})
    )
    w1 = s1["serving.wan_bytes"]["-"]["value"]
    w2 = s2["serving.wan_bytes"]["-"]["value"]
    # scalar path sums f32 sizes, batch path folds f64: approx only
    assert w1 == pytest.approx(w2 / 2.0, rel=1e-6)
    if "serving.wan_bytes_link" in s2:
        assert "serving.wan_bytes_link" in s1
