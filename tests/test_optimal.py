import numpy as np

from repro.core.cost import total_cost
from repro.core.latency import make_paper_env
from repro.core.optimal import solve_coordinate_descent, solve_exact_tiny
from repro.core.patterns import Pattern, Workload


def _tiny():
    env = make_paper_env()
    D = env.n_dcs
    n_items = 4
    pats = [
        Pattern(0, np.array([0, 1]), r_py=np.eye(D)[1] * 50, w_py=np.zeros(D)),
        Pattern(1, np.array([2, 3]), r_py=np.eye(D)[3] * 30, w_py=np.eye(D)[3] * 2),
    ]
    wl = Workload.from_patterns(pats, n_items, D)
    sizes = np.full(n_items, 100.0, np.float32)
    primary = np.array([0, 0, 2, 2])
    return env, wl, sizes, primary


def test_coordinate_descent_improves():
    env, wl, sizes, primary = _tiny()
    from repro.core.cost import PlacementState

    base = PlacementState.empty(wl.n_items, env.n_dcs)
    base.delta[np.arange(wl.n_items), primary] = True
    base.route_nearest(env)
    c_base = total_cost(wl.patterns, base, wl.r_xy, wl.w_xy, sizes, env).total
    state, c_opt = solve_coordinate_descent(wl, env, sizes, primary, max_rounds=3)
    assert c_opt <= c_base + 1e-12
    # solution keeps primaries
    assert state.delta[np.arange(wl.n_items), primary].all()


def test_exact_enumeration_improves_on_baseline():
    env, wl, sizes, primary = _tiny()
    from repro.core.cost import PlacementState

    base = PlacementState.empty(wl.n_items, env.n_dcs)
    base.delta[np.arange(wl.n_items), primary] = True
    base.route_nearest(env)
    c_base = total_cost(wl.patterns, base, wl.r_xy, wl.w_xy, sizes, env).total
    state, c_star = solve_exact_tiny(wl, env, sizes, primary, max_enum_items=4)
    # the do-nothing assignment is in the enumeration -> never worse
    assert c_star <= c_base + 1e-12
    assert state.delta[np.arange(wl.n_items), primary].all()
