"""Streaming-update subsystem: delta overlay, incremental layer repair,
warm-started DHD, and the cost-bounded migration planner."""
import numpy as np
import pytest

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env, make_synthetic_env
from repro.core.layered_graph import build_layered_graph, repair_layered_graph
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.streaming import (
    DeltaGraph,
    MutationLog,
    StreamingHeat,
    compact_workload,
    random_churn_batch,
)


def _random_graph(n, m, n_dcs, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, n_dcs, n)
    ), rng


# ------------------------------------------------------------- delta overlay
def test_delta_csr_matches_rebuilt_csr():
    g, rng = _random_graph(120, 600, 4, 0)
    dg = DeltaGraph(g)
    for _ in range(3):
        batch = random_churn_batch(dg, 0.08, rng)
        dg.apply(batch)
    # overlay adjacency == CSR rebuilt from the alive edge list, per vertex
    alive = np.where(dg.edge_alive)[0]
    ref = build_csr(
        dg.g.n_nodes, dg.g.src[alive], dg.g.dst[alive],
        weights=alive.astype(np.float32),
    )
    for u in range(dg.g.n_nodes):
        nbr, eid = dg.adj.out_edges(u, dg.edge_alive)
        lo, hi = int(ref.indptr[u]), int(ref.indptr[u + 1])
        assert sorted(eid.tolist()) == sorted(ref.weights[lo:hi].astype(int).tolist())
        assert sorted(nbr.tolist()) == sorted(ref.indices[lo:hi].tolist())


def test_delta_graph_tombstones_cascade():
    g, _ = _random_graph(30, 200, 3, 1)
    dg = DeltaGraph(g)
    log = MutationLog(g.n_nodes)
    victim = 7
    log.delete_vertex(victim)
    res = dg.apply(log.seal())
    assert not dg.node_alive[victim]
    incident = (dg.g.src == victim) | (dg.g.dst == victim)
    assert not dg.edge_alive[incident].any()
    assert set(np.where(incident)[0]) == set(res.dead_edge_ids.tolist())


def test_mutation_log_provisional_vertex_ids():
    g, _ = _random_graph(20, 60, 2, 2)
    dg = DeltaGraph(g)
    log = MutationLog(g.n_nodes)
    v = log.add_vertex(partition=1)
    assert v == g.n_nodes
    log.add_edge(v, 3)
    res = dg.apply(log.seal())
    assert dg.g.n_nodes == g.n_nodes + 1
    e = res.new_edge_ids[0]
    assert (int(dg.g.src[e]), int(dg.g.dst[e])) == (v, 3)


# -------------------------------------------------- incremental layer repair
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_repair_matches_rebuild(seed):
    """Randomized churn: repaired layered graph == from-scratch rebuild."""
    env = make_synthetic_env(8, "high", seed=seed)
    g, rng = _random_graph(250, 1200, 8, seed + 10)
    lg = build_layered_graph(g, env)
    dg = DeltaGraph(g)
    for _ in range(4):
        batch = random_churn_batch(dg, 0.06, rng)
        dg.apply(batch)
        lg, stats = repair_layered_graph(lg, dg.g, dg.edge_alive)
        gc, vmap, emap = dg.compact()
        ref = build_layered_graph(gc, env, thresholds_s=lg.thresholds_s)

        # same layer per alive edge
        alive = np.where(dg.edge_alive)[0]
        assert np.array_equal(lg.edge_layer[alive], ref.edge_layer[emap[alive]])
        assert (lg.edge_layer[~dg.edge_alive] == -1).all()
        # identical DC components at every layer
        assert np.array_equal(lg.comp_of_dc, ref.comp_of_dc)
        # identical bridge subgraphs (edge sets compared through the id map)
        def canon(l, use_emap):
            out = set()
            for layer in l.layers:
                for b in layer:
                    edges = emap[b.edge_ids] if use_emap else b.edge_ids
                    out.add((
                        b.layer, b.comp, frozenset(int(e) for e in edges),
                        tuple(sorted(int(d) for d in b.dcs)),
                        tuple(sorted(b.children)),
                    ))
            return out
        assert canon(lg, True) == canon(ref, False)
        np.testing.assert_allclose(
            lg.mean_layer_latency, ref.mean_layer_latency, rtol=1e-12
        )


def test_repair_relevels_only_dirty_layers():
    """A batch confined to existing DC pairs must not relabel any layer; a
    batch opening a brand-new DC pair must relabel from that layer up."""
    env = make_synthetic_env(6, "high", seed=4)
    rng = np.random.default_rng(5)
    # two DC islands: {0,1,2} and {3,4,5} with no cross-island edges
    n = 60
    part = np.concatenate([rng.integers(0, 3, n // 2), rng.integers(3, 6, n // 2)])
    src, dst = [], []
    for _ in range(300):
        u, v = rng.integers(0, n // 2, 2)
        if u != v:
            src.append(u), dst.append(v)
    for _ in range(300):
        u, v = rng.integers(n // 2, n, 2)
        if u != v:
            src.append(u), dst.append(v)
    g = Graph.from_edges(n, src, dst, partition=part)
    lg = build_layered_graph(g, env)
    dg = DeltaGraph(g)

    # duplicate an existing edge: layer membership changes, pairs don't
    log = MutationLog(n)
    log.add_edge(int(g.src[0]), int(g.dst[0]))
    dg.apply(log.seal())
    lg, stats = repair_layered_graph(lg, dg.g, dg.edge_alive)
    assert stats.first_dirty is None

    # bridge the islands: a new DC pair appears -> relabel from its layer
    u = int(np.where(part[: n // 2] == 0)[0][0])
    v = int(n // 2 + np.where(part[n // 2:] == 3)[0][0])
    log = MutationLog(n)
    log.add_edge(u, v)
    dg.apply(log.seal())
    lg, stats = repair_layered_graph(lg, dg.g, dg.edge_alive)
    assert stats.first_dirty is not None
    assert stats.relabeled_layers >= 1
    gc, vmap, emap = dg.compact()
    ref = build_layered_graph(gc, env, thresholds_s=lg.thresholds_s)
    assert np.array_equal(lg.comp_of_dc, ref.comp_of_dc)
    # the islands are now merged at the top layer
    assert len(np.unique(lg.comp_of_dc[lg.n_layers])) == 1


# --------------------------------------------------------------- warm DHD
def test_warm_dhd_matches_cold_steady_state():
    g, rng = _random_graph(200, 900, 4, 7)
    w = rng.uniform(0.1, 1.0, g.n_edges).astype(np.float32)
    q = rng.uniform(0.0, 1.0, g.n_nodes).astype(np.float32)

    sh = StreamingHeat()
    cold0 = sh.rebuild(g.n_nodes, g.src, g.dst, w, q)
    assert cold0 < sh.max_iters  # converged

    # mutate: drop 30 edges, add 30 edges
    dead = rng.choice(g.n_edges, 30, replace=False)
    keep = np.ones(g.n_edges, bool)
    keep[dead] = False
    ns = rng.integers(0, g.n_nodes, 30)
    nd = (ns + 1 + rng.integers(0, g.n_nodes - 1, 30)) % g.n_nodes
    nw = rng.uniform(0.1, 1.0, 30).astype(np.float32)
    src2 = np.concatenate([g.src[keep], ns.astype(np.int32)])
    dst2 = np.concatenate([g.dst[keep], nd.astype(np.int32)])
    w2 = np.concatenate([w[keep], nw])
    touched = np.unique(np.concatenate([g.src[dead], g.dst[dead], ns, nd]))

    stats = sh.update(g.n_nodes, src2, dst2, w2, q, touched)
    ref = StreamingHeat()
    ref_iters = ref.rebuild(g.n_nodes, src2, dst2, w2, q)

    np.testing.assert_allclose(sh.vertex_heat, ref.vertex_heat, atol=1e-4)
    # warm start converges in no more sweeps than the cold solve
    assert stats.global_iters <= ref_iters


def test_warm_dhd_handles_vertex_growth():
    g, rng = _random_graph(150, 500, 3, 8)
    w = np.ones(g.n_edges, np.float32)
    q = rng.uniform(0.0, 1.0, g.n_nodes).astype(np.float32)
    sh = StreamingHeat()
    sh.rebuild(g.n_nodes, g.src, g.dst, w, q)
    n2 = g.n_nodes + 5
    ns = np.arange(g.n_nodes, n2, dtype=np.int32)
    nd = rng.integers(0, g.n_nodes, 5).astype(np.int32)
    src2 = np.concatenate([g.src, ns])
    dst2 = np.concatenate([g.dst, nd])
    w2 = np.concatenate([w, np.ones(5, np.float32)])
    q2 = np.concatenate([q, rng.uniform(0.0, 1.0, 5).astype(np.float32)])
    sh.update(n2, src2, dst2, w2, q2, touched=np.concatenate([ns, nd]))
    ref = StreamingHeat()
    ref.rebuild(n2, src2, dst2, w2, q2)
    np.testing.assert_allclose(sh.vertex_heat, ref.vertex_heat, atol=1e-4)


# ------------------------------------------------------------- store + plan
@pytest.fixture(scope="module")
def churned_store():
    g = _random_graph(220, 1400, 4, 11)[0]
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 24, seed=3, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4)
    )
    rng = np.random.default_rng(12)
    store._delta_graph = DeltaGraph(store.g)
    reports = [
        store.apply_updates(random_churn_batch(store._delta_graph, 0.02, rng))
        for _ in range(3)
    ]
    return store, reports


def test_apply_updates_keeps_routing_closed(churned_store):
    """After churn every pattern stays fully servable and the routing/
    placement invariants (constraints a/b/e) hold."""
    store, reports = churned_store
    ok = store.constraints()
    assert ok["a_route_on_replica"]
    assert ok["a_requested_routed"]
    assert ok["b_pattern_route_on_replica"]
    for p in store.workload.patterns:
        if not len(p.items):
            continue
        res = store.serve_online(p, int(np.argmax(p.r_py)))
        assert res.n_missing == 0


def test_apply_updates_matches_full_rebuild_coverage(churned_store):
    """Incremental maintenance serves the same workload as a from-scratch
    rebuild of the final graph: same coverage, cost of the same order."""
    store, _ = churned_store
    gc, vmap, emap = store._delta_graph.compact()
    wl2 = compact_workload(store.workload, store.g.n_nodes, gc, vmap, emap)
    rebuilt = GeoGraphStore(
        gc, store.env, wl2, config=PlacementConfig(precache=False, dhd_steps=4)
    )
    for p_inc, p_reb in zip(store.workload.patterns, rebuilt.workload.patterns):
        if not len(p_inc.items):
            continue
        origin = int(np.argmax(p_inc.r_py))
        r_inc = store.serve_online(p_inc, origin)
        r_reb = rebuilt.serve_online(p_reb, origin)
        assert r_inc.n_missing == r_reb.n_missing == 0
        assert len(p_inc.items) == len(p_reb.items)


def test_layered_graph_stays_rebuild_identical_in_store(churned_store):
    store, _ = churned_store
    gc, vmap, emap = store._delta_graph.compact()
    ref = build_layered_graph(gc, store.env, thresholds_s=store.lg.thresholds_s)
    assert np.array_equal(store.lg.comp_of_dc, ref.comp_of_dc)


def test_flush_migrations_budget_and_constraints(churned_store):
    store, _ = churned_store
    sizes = store.g.item_size()
    before = store.constraints()
    budget = 0.01 * float(sizes.sum())
    plan = store.flush_migrations(budget_bytes=budget)
    assert plan.wan_bytes <= budget + 1e-9
    after = store.constraints()
    for k, held in before.items():
        if held:
            assert after[k], f"migration regressed constraint {k}"
    # every add landed, every drop (net of rollbacks) cleared
    for m in plan.moves:
        assert store.state.delta[m.item, m.dc] == (m.kind == "add")


def test_flush_migrations_zero_budget_adds_nothing(churned_store):
    store, _ = churned_store
    plan = store.flush_migrations(budget_bytes=0.0)
    assert plan.n_adds == 0
    assert plan.wan_bytes == 0.0
