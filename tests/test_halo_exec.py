"""Halo-exchange message passing (shard_map): correctness vs dense reference
and measured wire-byte reduction.  Runs in a subprocess with 8 fake devices
(the main test process must keep the default single-device view)."""
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.graph import Graph
from repro.distributed.halo_exec import build_halo_program, exchange_stats


def test_program_structure():
    rng = np.random.default_rng(0)
    n, m, P_ = 32, 80, 4
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    g = Graph.from_edges(n, src[keep], dst[keep], partition=rng.integers(0, P_, n))
    prog = build_halo_program(g, P_)
    # every edge lands on its dst's shard exactly once
    assert int(prog.edge_mask.sum()) == int(keep.sum())
    # send lists reference valid local rows
    for p in range(P_):
        sizes = len(prog.local_ids[p])
        assert (prog.send_idx[p][prog.send_mask[p]] < sizes).all()
    st = exchange_stats(prog, d=8, n_layers=2)
    assert st["halo_bytes_per_device"] < st["allgather_bytes_per_device"]


def test_halo_matches_reference_8dev():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.graph import Graph
        from repro.distributed.halo_exec import build_halo_program, run_message_passing
        rng = np.random.default_rng(0)
        n, m, P_ = 64, 200, 8
        src = rng.integers(0, n, m); dst = rng.integers(0, n, m)
        keep = src != dst; src, dst = src[keep], dst[keep]
        g = Graph.from_edges(n, src, dst, partition=rng.integers(0, P_, n))
        prog = build_halo_program(g, P_)
        d = 16
        feats = rng.standard_normal((n, d)).astype(np.float32)
        w = jnp.asarray(rng.standard_normal((d, d)) * 0.1, jnp.float32)
        mesh = Mesh(np.array(jax.devices()[:P_]), ("x",))
        fs = jnp.asarray(prog.scatter_features(feats))
        oh = prog.gather_outputs(np.asarray(
            run_message_passing(prog, mesh, fs, w, n_layers=3, mode="halo")), n)
        oa = prog.gather_outputs(np.asarray(
            run_message_passing(prog, mesh, fs, w, n_layers=3, mode="allgather")), n)
        x = jnp.asarray(feats)
        for _ in range(3):
            msg = x[src] @ w
            agg = jax.ops.segment_sum(msg, jnp.asarray(dst), num_segments=n)
            deg = jax.ops.segment_sum(jnp.ones(len(dst)), jnp.asarray(dst), num_segments=n)
            x = x + jnp.tanh(agg / jnp.maximum(deg, 1.0)[:, None])
        ref = np.asarray(x)
        assert np.abs(oh - ref).max() < 1e-4, np.abs(oh - ref).max()
        assert np.abs(oa - ref).max() < 1e-4
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
    )
    assert "OK" in out.stdout, out.stderr[-2000:]
