"""Serving control plane: StoreClient / AdmissionController / MaintenancePolicy.

Bars under test:
  * controller routes are request-for-request identical to
    ``GeoGraphStore.serve_batch`` on the exact batches it formed (and hence
    to ``route_online``);
  * deadline-miss accounting is exact and the AIMD loop reacts (shrink on
    miss, growth under slack);
  * per-origin round-robin fairness: an adversarial flood from one hot DC
    cannot starve the other origins (global FIFO provably does);
  * maintenance interleaving is *equivalent* to back-to-back
    ``flush_migrations`` + ``maintain`` — identical final replica sets and
    routes — and measured wave times feed back into the transfer window;
  * the controller preserves its queue across a mid-drain exception (the
    contract the removed ``GraphFrontend`` shim used to carry).
"""
import math

import numpy as np
import pytest

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.routing import route_online
from repro.core.store import GeoGraphStore
from repro.serve import (
    AdmissionConfig,
    AdmissionController,
    MaintenanceConfig,
    MaintenancePolicy,
    StoreClient,
)
from repro.streaming import DeltaGraph, random_churn_batch


def _random_graph(n, m, n_dcs, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, n_dcs, n)
    )


def _store(seed=0, n=220, m=1400, n_pats=24):
    g = _random_graph(n, m, 4, seed)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_pats, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4)
    )


def _churned_store(seed, n_batches=3, rate=0.02):
    store = _store(seed)
    rng = np.random.default_rng(seed + 100)
    store._delta_graph = DeltaGraph(store.g)
    for _ in range(n_batches):
        store.apply_updates(random_churn_batch(store._delta_graph, rate, rng))
    return store


def _trace(store, n, seed, dt=0.002):
    """(t, items, origin) stream with the 65% home / 35% remote origin mix."""
    rng = np.random.default_rng(seed)
    pats = [p for p in store.workload.patterns if len(p.items)]
    d = store.env.n_dcs
    t = 0.0
    out = []
    for _ in range(n):
        p = pats[int(rng.integers(0, len(pats)))]
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, d))
        t += float(rng.exponential(dt))
        out.append((t, p.items, origin))
    return out


class _RecordingStore:
    """Proxy that records every batch handed to ``serve_batch`` verbatim."""

    def __init__(self, store):
        self.store = store
        self.batches = []

    def serve_batch(self, reqs):
        self.batches.append([(np.asarray(it), int(o)) for it, o in reqs])
        return self.store.serve_batch(reqs)


# ------------------------------------------------------------ route parity
def test_controller_routes_match_serve_batch_on_formed_batches():
    """The acceptance bar: replaying the exact batches the controller formed
    through the data plane yields the same results, request for request."""
    store = _store(0)
    rec = _RecordingStore(store)
    ctl = AdmissionController(rec, AdmissionConfig())
    client = StoreClient(ctl)
    handles = [
        client.submit(items, origin, at=t) for t, items, origin in _trace(store, 160, 7)
    ]
    done = ctl.run_until_idle()
    assert len(done) == len(handles) and all(h.done for h in handles)
    assert sum(len(b) for b in rec.batches) == len(handles)
    served = iter(done)  # completion order == concatenation of formed batches
    for batch in rec.batches:
        replay = store.serve_batch(batch, observe=False)
        for (items, origin), ref in zip(batch, replay):
            h = next(served)
            assert h.origin == origin and np.array_equal(h.items, items)
            assert np.array_equal(h.result.served_by, ref.served_by)
            assert h.result.latency_s == ref.latency_s
            assert h.result.n_missing == ref.n_missing
    # and therefore identical to the scalar router per request
    for h in handles[:24]:
        ref = route_online(store.lg, store.state, h.items, h.origin)
        assert np.array_equal(h.result.served_by, ref.served_by)


def test_handles_are_futures():
    store = _store(1)
    ctl = AdmissionController(store)
    client = StoreClient(ctl)
    h = client.submit(store.workload.patterns[0].items, 0, at=5.0)
    assert not h.done
    with pytest.raises(RuntimeError):
        h.value()
    res = client.result(h)  # drains the controller
    assert h.done and res is h.result
    assert h.t_done >= h.t_dispatch >= h.t_submit == 5.0
    assert math.isfinite(h.latency_s) and h.latency_s >= 0.0


# ----------------------------------------------------- deadlines + adaptivity
def test_deadline_miss_accounting_and_shrink():
    store = _store(2)
    cfg = AdmissionConfig(initial_batch=32, min_batch=2)
    ctl = AdmissionController(store, cfg)
    client = StoreClient(ctl)
    # impossible deadlines: even the dispatch overhead alone exceeds them
    handles = [
        client.submit(items, origin, at=t, deadline_s=1e-6)
        for t, items, origin in _trace(store, 80, 3)
    ]
    ctl.run_until_idle()
    assert all(h.deadline_missed for h in handles)
    assert ctl.deadline_misses == len(handles)
    assert ctl.metrics()["deadline_misses"] == len(handles)
    # AIMD shrank the target to the floor under sustained violation
    assert ctl.batch_target == cfg.min_batch
    targets = [b.target for b in ctl.history]
    assert targets[0] == 32 and any(t < 32 for t in targets)


def test_adaptive_grows_under_slack():
    store = _store(3)
    cfg = AdmissionConfig(initial_batch=4, max_batch=128)
    ctl = AdmissionController(store, cfg)
    client = StoreClient(ctl)
    # generous deadlines + backlogged queue -> the target should climb
    for t, items, origin in _trace(store, 300, 5, dt=1e-5):
        client.submit(items, origin, at=t, deadline_s=60.0)
    ctl.run_until_idle()
    assert ctl.completed == 300
    assert ctl.batch_target > cfg.initial_batch
    assert max(b.size for b in ctl.history) > cfg.initial_batch


# ------------------------------------------------------------------ fairness
def test_round_robin_fairness_under_origin_flood():
    """Adversarial skew: origin 0 floods the queue before a trickle from the
    other origins arrives.  Round-robin formation must serve the trickle
    within the first few batches; global FIFO (the old frontend order)
    provably starves it until the flood drains."""
    store = _store(4)
    pats = [p for p in store.workload.patterns if len(p.items)]
    flood_n, trickle_per_origin = 480, 5

    def run(fairness):
        ctl = AdmissionController(
            store,
            AdmissionConfig(
                policy="greedy", fairness=fairness, max_batch=64, quantum=8
            ),
        )
        client = StoreClient(ctl)
        flood = [
            client.submit(pats[i % len(pats)].items, 0, at=0.0)
            for i in range(flood_n)
        ]
        trickle = [
            client.submit(pats[i % len(pats)].items, o, at=1e-9)
            for o in range(1, store.env.n_dcs)
            for i in range(trickle_per_origin)
        ]
        done = ctl.run_until_idle()
        pos = {h.rid: i for i, h in enumerate(done)}
        return flood, trickle, pos

    flood, trickle, pos = run("round_robin")
    worst = max(pos[h.rid] for h in trickle)
    # every trickle request drains within ~2 batches' worth of requests
    assert worst < 3 * 64, f"trickle starved to position {worst}"
    _, trickle_fifo, pos_fifo = run("fifo")
    assert min(pos_fifo[h.rid] for h in trickle_fifo) >= flood_n


def test_priority_classes_drain_first():
    store = _store(5)
    pats = [p for p in store.workload.patterns if len(p.items)]
    ctl = AdmissionController(
        store, AdmissionConfig(policy="greedy", max_batch=32, quantum=8)
    )
    client = StoreClient(ctl)
    bulk = [client.submit(pats[i % len(pats)].items, 0, priority=1) for i in range(96)]
    inter = [client.submit(pats[i % len(pats)].items, 1, priority=0) for i in range(8)]
    done = ctl.run_until_idle()
    pos = {h.rid: i for i, h in enumerate(done)}
    assert max(pos[h.rid] for h in inter) < min(pos[h.rid] for h in bulk)


# ------------------------------------------------- maintenance interleaving
def _tight_window(store, n_items_per_wave=3.0):
    med = float(np.median(store.g.item_size()))
    bw_min = float(store.env.bw_Bps_safe().min())
    return n_items_per_wave * med / bw_min


def test_policy_interleaving_equals_back_to_back():
    """Waves applied piecemeal into idle gaps + one deferred maintain must
    land the exact final replica sets and routes of an inline
    ``flush_migrations`` + ``maintain``."""
    s_pol = _churned_store(6)
    s_ref = _churned_store(6)
    kw = dict(theta_add=0.3, theta_drop=0.15)
    window = _tight_window(s_pol)

    policy = MaintenancePolicy(
        s_pol,
        MaintenanceConfig(
            window_s=window, maintain_every_s=1e9, plan_kw=kw,
            # gaps are transfer-window sized; the simulated maintain charge
            # must fit one or the deferred maintain never fires
            maintain_cost_s=0.0,
        ),
    )
    policy.request_flush()
    # drip-feed idle gaps so waves land one or two at a time
    now, used_total, gaps = 0.0, 0.0, 0
    while policy.flush_in_progress or policy.n_flushes == 0 or policy.n_maintains == 0:
        used_total += policy.on_idle(now, window * 2)
        now += window * 2
        gaps += 1
        assert gaps < 1000, "policy made no progress"
    plan_pol = policy.plans[0]

    plan_ref = s_ref.flush_migrations(window_s=window, **kw)
    s_ref.maintain(diffusion_steps=4)

    assert [(m.item, m.dc, m.kind) for m in plan_pol.moves] == [
        (m.item, m.dc, m.kind) for m in plan_ref.moves
    ]
    if plan_pol.n_adds:
        assert policy.n_waves == plan_pol.schedule.n_waves >= 1
        assert gaps > 1  # the flush really was split across idle gaps
    assert np.array_equal(s_pol.state.delta, s_ref.state.delta)
    assert np.array_equal(s_pol.state.route, s_ref.state.route)
    assert s_pol.route_index.verify(s_pol.state.delta)
    assert policy.n_maintains == 1


def test_measured_wave_times_close_the_window_loop():
    """Links shipping slower than the Eq. 1 estimate must shrink the next
    flush's transfer window (and faster links widen it)."""
    store = _churned_store(7)
    window = _tight_window(store)
    slow = MaintenancePolicy(
        store,
        MaintenanceConfig(
            window_s=window, plan_kw=dict(theta_add=0.3, theta_drop=0.15)
        ),
        measure_wave=lambda w: 2.0 * w.makespan_s,  # links half as fast
    )
    slow.request_flush()
    slow.drain(now=0.0)
    assert slow.n_waves >= 1
    assert slow.window_gain < 1.0
    assert slow.effective_window() == pytest.approx(window * slow.window_gain)
    gain_before = slow.window_gain
    slow.request_flush()
    slow.drain(now=1.0)
    assert slow.plans[1].schedule.window_s == pytest.approx(window * gain_before)

    fast = MaintenancePolicy(
        _churned_store(7),
        MaintenanceConfig(
            window_s=window, plan_kw=dict(theta_add=0.3, theta_drop=0.15)
        ),
        measure_wave=lambda w: 0.5 * w.makespan_s,
    )
    fast.request_flush()
    fast.drain(now=0.0)
    assert fast.window_gain > 1.0


def test_stale_flush_guard_and_replan():
    """A mutation batch landing between waves must not let stale rows apply:
    the applier raises StaleFlushError and the policy re-plans next gap."""
    from repro.streaming.migration import StaleFlushError

    store = _churned_store(12)
    window = _tight_window(store)
    kw = dict(theta_add=0.3, theta_drop=0.15)
    plan, applier = store.begin_flush(window_s=window, **kw)
    if applier.n_remaining < 1:
        pytest.skip("plan produced no transfer waves")
    applier.apply_next()
    store.apply_updates(
        random_churn_batch(store._delta_graph, 0.01, np.random.default_rng(1))
    )
    with pytest.raises(StaleFlushError):
        applier.apply_next() if applier.n_remaining else applier.finish()
    assert store.route_index.verify(store.state.delta)  # nothing stale landed

    # policy path: the abandoned flush re-arms and re-plans in the next gap
    policy = MaintenancePolicy(
        store, MaintenanceConfig(window_s=window, plan_kw=kw)
    )
    policy.request_flush()
    policy.on_idle(0.0, window)  # begins + lands at most a wave or two
    if policy.flush_in_progress:
        store.apply_updates(
            random_churn_batch(store._delta_graph, 0.01, np.random.default_rng(2))
        )
        policy.on_idle(1.0, window)  # trips the guard, re-arms
        assert policy.n_stale_flushes == 1
        assert not policy.flush_in_progress
        policy.drain(now=2.0)  # fresh plan against the new id space
        assert policy.n_flushes == 2
    assert store.route_index.verify(store.state.delta)


def test_compaction_remaps_inflight_handles():
    """The controller subscribes to the store's remap hook, so the policy
    may compact during idle gaps while requests are scheduled: their item
    rows re-key instead of dangling."""
    store = _churned_store(13, n_batches=4, rate=0.04)
    if store.tombstone_ratio() == 0.0:
        pytest.skip("churn produced no tombstones")
    policy = MaintenancePolicy(
        store, MaintenanceConfig(compact_ratio=1e-9, compact_cost_s=1e-6)
    )
    ctl = AdmissionController(store, AdmissionConfig(), policy=policy)
    assert ctl._remap_registered
    client = StoreClient(ctl)
    pats = [p for p in store.workload.patterns if len(p.items)]
    handles = [
        client.submit_pattern(pats[i % len(pats)], 0, at=0.1 * (i + 1))
        for i in range(6)
    ]
    done = ctl.run_until_idle()
    assert len(done) == 6 and all(h.done for h in handles)
    assert policy.n_compactions == 1  # fired inside an idle gap
    assert store.tombstone_ratio() == 0.0
    assert store.route_index.verify(store.state.delta)
    # remapped rows are in range and the served routes reference live rows
    for h in handles:
        assert len(h.items) == 0 or int(h.items.max()) < store.g.n_items


def test_mutation_growth_remaps_inflight_handles():
    """Vertex inserts shift every edge-item row; queued handles must re-key
    through the same growth map the store's own state grew through, and a
    same-batch compaction must compose on top of it."""
    store = _churned_store(14, n_batches=1, rate=0.01)
    ctl = AdmissionController(store, AdmissionConfig())
    client = StoreClient(ctl)
    # requests that deliberately reference edge items (rows >= n_nodes)
    edge_rows = store.g.n_nodes + np.arange(0, 12, dtype=np.int64)
    uid_before = store._item_uid[edge_rows].copy()
    handles = [client.submit(edge_rows.copy(), 0, at=10.0) for _ in range(3)]
    store.apply_updates(
        random_churn_batch(store._delta_graph, 0.03, np.random.default_rng(5))
    )
    for h in handles:
        live = h.items  # remapped in place by the growth listener
        # every surviving row still denotes the same item (uid-stable)
        uid_now = store._item_uid[live]
        assert np.all(np.isin(uid_now, uid_before))
        assert len(live) == 0 or int(live.max()) < store.g.n_items
    done = ctl.run_until_idle()
    assert len(done) == 3 and all(h.result.n_missing == 0 for h in handles)
    # and across the reactive-compaction path (growth + compact in one batch)
    store.compact_ratio = 1e-9
    h2 = client.submit(store.g.n_nodes + np.arange(0, 8, dtype=np.int64), 1, at=20.0)
    uid2 = store._item_uid[h2.items].copy()
    store.apply_updates(
        random_churn_batch(store._delta_graph, 0.03, np.random.default_rng(6))
    )
    assert np.all(np.isin(store._item_uid[h2.items], uid2))
    ctl.run_until_idle()
    assert h2.done and h2.result.n_missing == 0


def test_plan_flush_rejects_unknown_packing_without_window():
    store = _churned_store(15, n_batches=1)
    with pytest.raises(ValueError, match="unknown packing"):
        store.flush_migrations(window_s=None, schedule="bogus")


def test_policy_proactive_compaction():
    store = _churned_store(8, n_batches=4, rate=0.04)
    if store.tombstone_ratio() == 0.0:
        pytest.skip("churn produced no tombstones")
    policy = MaintenancePolicy(store, MaintenanceConfig(compact_ratio=1e-9))
    used = policy.drain(now=0.0)
    assert policy.n_compactions == 1
    assert used >= policy.cfg.compact_cost_s
    assert store.tombstone_ratio() == 0.0
    assert store.route_index.verify(store.state.delta)


def test_controller_offers_idle_gaps_to_policy():
    """End-to-end: an armed flush lands between serving drains, and serving
    results stay placement-consistent at every point."""
    store = _churned_store(9)
    window = _tight_window(store)
    policy = MaintenancePolicy(
        store,
        MaintenanceConfig(window_s=window, plan_kw=dict(theta_add=0.3, theta_drop=0.15)),
    )
    ctl = AdmissionController(store, AdmissionConfig(), policy=policy)
    client = StoreClient(ctl)
    policy.request_flush()
    # sparse arrivals -> real idle gaps between drains
    handles = [
        client.submit(items, origin, at=t * 50.0)
        for t, items, origin in _trace(store, 40, 11)
    ]
    ctl.run_until_idle()
    if not policy.flush_in_progress and policy.n_flushes == 0:
        policy.drain(now=ctl.clock.now())
    assert all(h.done for h in handles)
    assert policy.n_flushes == 1
    assert not policy.flush_in_progress  # flush completed inside the gaps
    # waves landed between drains, never mid-batch: the route table the
    # final state exposes is still rebuild-identical
    assert store.route_index.verify(store.state.delta)


# --------------------------------------------------------- shim retirement
def test_graph_frontend_shim_is_gone():
    """The deprecated ``GraphFrontend``/``GraphRequest`` shim is removed: the
    names no longer import, and the controller stack is the one entry point."""
    import repro.serve as serve

    assert "GraphFrontend" not in serve.__all__
    assert "GraphRequest" not in serve.__all__
    with pytest.raises(AttributeError):
        serve.GraphFrontend
    with pytest.raises(ImportError):
        import repro.serve.graph_frontend  # noqa: F401


def test_controller_preserves_queue_across_exception():
    """The mid-drain-exception contract the shim used to carry, now native to
    the controller's requeue path."""

    class _Flaky:
        def __init__(self, store):
            self.store = store
            self.failures_left = 1

        def serve_batch(self, reqs):
            if self.failures_left:
                self.failures_left -= 1
                raise RuntimeError("transient")
            return self.store.serve_batch(reqs)

    store = _store(11)
    pats = [p for p in store.workload.patterns if len(p.items)]
    ctl = AdmissionController(
        _Flaky(store),
        AdmissionConfig(policy="greedy", fairness="fifo", max_batch=4),
    )
    client = StoreClient(ctl)
    rids = [
        client.submit(p.items, 0, deadline_s=math.inf).rid for p in pats[:10]
    ]
    with pytest.raises(RuntimeError):
        ctl.run_until_idle()
    assert ctl.pending == 10 and ctl.completed == 0
    assert [h.rid for h in ctl.pending_handles()] == rids  # FIFO order intact
    done = ctl.run_until_idle()
    assert sorted(h.rid for h in done) == rids and ctl.pending == 0


# ---------------------------------------------------- measured service model
class _TimedStore:
    """Store stub reporting a fixed measured serving time per drain."""

    def __init__(self, store, seconds):
        self.store = store
        self.seconds = seconds

    def serve_batch(self, reqs):
        out = self.store.serve_batch(reqs)
        self.last_serve_seconds = self.seconds
        return out


def test_measured_service_model_charges_store_time():
    store = _store(13)
    timed = _TimedStore(store, 0.125)
    ctl = AdmissionController(
        timed, AdmissionConfig(service_model="measured")
    )
    client = StoreClient(ctl)
    pats = [p for p in store.workload.patterns if len(p.items)]
    for i in range(8):
        client.submit(pats[i % len(pats)].items, 0, at=0.0)
    ctl.run_until_idle()
    assert ctl.history
    # every drain charged exactly the store's measured seconds, not the
    # linear occupancy model
    assert all(b.compute_s == 0.125 for b in ctl.history)


def test_measured_service_model_wall_clock_fallback():
    """A store without ``last_serve_seconds`` falls back to the drain's own
    wall clock — still positive, never the occupancy constants."""

    class _Bare:
        def __init__(self, store):
            self._s = store

        def serve_batch(self, reqs):
            return self._s.serve_batch(reqs)

    store = _store(14)
    ctl = AdmissionController(
        _Bare(store), AdmissionConfig(service_model="measured")
    )
    client = StoreClient(ctl)
    pats = [p for p in store.workload.patterns if len(p.items)]
    for i in range(4):
        client.submit(pats[i % len(pats)].items, 0, at=0.0)
    ctl.run_until_idle()
    assert all(b.compute_s > 0.0 for b in ctl.history)


def test_real_store_reports_last_serve_seconds():
    store = _store(15)
    pats = [p for p in store.workload.patterns if len(p.items)]
    assert store.last_serve_seconds == 0.0
    store.serve_batch([(pats[0].items, 0), (pats[1].items, 1)])
    assert store.last_serve_seconds > 0.0


def test_service_model_validated():
    with pytest.raises(ValueError, match="service_model"):
        AdmissionConfig(service_model="psychic")
