"""Fig. 11 — sensitivity to network heterogeneity (low/medium/high).

Paper: GeoLayer speedup grows with heterogeneity: 1.7x / 1.9x / 2.4x mean."""
from __future__ import annotations

from typing import Dict

from repro.core.latency import make_synthetic_env

from .common import csv_row, make_setup, mean_online_latency, strategy_store


def run(fast: bool = True) -> Dict[str, Dict[str, float]]:
    n_hist, n_test = (100, 30) if fast else (400, 100)
    out = {}
    rows = []
    for het in ["low", "medium", "high"]:
        env = make_synthetic_env(8, heterogeneity=het, seed=11)
        setup = make_setup("snb", n_hist, n_test, env=env, n_dcs=8)
        lat = {}
        for strat in ["geolayer", "random", "top", "dcd"]:
            store = strategy_store(setup, strat)
            lat[strat] = mean_online_latency(store, setup.test_patterns)
        base = max(lat["geolayer"], 1e-9)
        speedups = {s: lat[s] / base for s in lat}
        out[het] = speedups
        rows.append(csv_row(f"fig11_{het}", lat["geolayer"] * 1e6,
                            " ".join(f"{s}={v:.2f}x" for s, v in speedups.items())))
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
