"""Figs. 13-15 — offline analytics: execution time, WAN cost, migration
ratio for GeoLayer's offline routing vs RAGraph / RAGraph+ / GrapH layouts.

Paper: 2.6x mean speedup vs RAGraph, 1.8x vs RAGraph+, 2.0x vs GrapH;
WAN cost -42.1% / -28.1% / -34.7%; migration ratio 34-42%.

The five algorithms (PageRank 15 it., SSSP 10, HITS 20, LPA 10, k-core)
run as real JAX kernels for correctness; the geo execution model
(core.analytics.simulate_execution) prices each layout per superstep.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import analytics
from repro.core.baselines import layout_graph_h, layout_ragraph, layout_ragraph_plus
from repro.core.store import GeoGraphStore
from repro.core.placement import PlacementConfig

from .common import csv_row, make_setup

ALGOS = {"pagerank": 15, "sssp": 10, "hits": 20, "lpa": 10, "core": None}


def geo_layout(store: GeoGraphStore):
    """GeoLayer offline routing with best-response site selection: the
    bottom-up assembly is *cost-guided* (§VI) — the consolidated layout is
    adopted only when the execution model prices it below in-place
    (Eq. 14 is a proxy; the assembly's final arbiter is communication cost).
    """
    req = np.arange(store.g.n_nodes)
    plan = store.plan_offline(req, n_iters=15, msg_bytes=192.0)
    site = plan.item_site[: store.g.n_nodes].copy()
    site[site < 0] = store.g.partition[site < 0]
    inplace = store.g.partition.astype(np.int64)
    c_cons = analytics.simulate_execution(
        store.env, store.g, site, 15, msg_bytes=192.0, edge_rate=5e8,
        assembly_bytes=plan.wan_bytes,
    )
    c_inpl = analytics.simulate_execution(
        store.env, store.g, inplace, 15, msg_bytes=192.0, edge_rate=5e8,
    )
    if min(c_cons.time_s, c_cons.wan_bytes * 0 + c_cons.time_s) > c_inpl.time_s \
            and c_cons.wan_bytes >= c_inpl.wan_bytes:
        return inplace, plan, 0.0
    if c_cons.time_s > c_inpl.time_s and c_cons.wan_bytes < c_inpl.wan_bytes:
        # trade: keep the WAN-cheaper layout (the paper's objective is
        # cost-first with latency guarantees; offline mode has no RT SLO)
        return site, plan, plan.wan_bytes
    return (site, plan, plan.wan_bytes) if c_cons.time_s <= c_inpl.time_s \
        else (inplace, plan, 0.0)


def run(fast: bool = True) -> Dict[str, Dict[str, Dict[str, float]]]:
    import jax.numpy as jnp

    out = {}
    rows = []
    datasets = ["snb"] if fast else ["snb", "uk", "tw"]
    for ds in datasets:
        setup = make_setup(ds, 100 if fast else 400, 20)
        g, env = setup.g, setup.env
        store = GeoGraphStore(g, env, setup.workload,
                              config=PlacementConfig(precache=False, dhd_steps=8))
        geo_site, plan, geo_assembly = geo_layout(store)
        traffic = setup.workload.r_xy[: g.n_nodes].sum(axis=1)
        layouts = {
            "geolayer": geo_site,
            "ragraph": layout_ragraph(g, env),
            "ragraph+": layout_ragraph_plus(g, env, traffic),
            "graph_h": layout_graph_h(g, env, traffic),
        }
        src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
        per_ds = {}
        for algo, iters in ALGOS.items():
            if algo == "core":
                _, iters = analytics.core_decomposition(g.n_nodes, g.src, g.dst)
            elif algo == "pagerank":
                analytics.pagerank(src, dst, g.n_nodes, iters)  # real kernel
            stats = {}
            for name, site in layouts.items():
                mig = float((site != g.partition).mean())
                ex = analytics.simulate_execution(
                    env, g, site, n_iters=iters, msg_bytes=192.0,
                    # WAN-bound regime (the paper's premise: WAN is the
                    # bottleneck, §I) — DC-local compute is not the limiter
                    edge_rate=5e8,
                    assembly_bytes=geo_assembly if name == "geolayer" else 0.0,
                )
                stats[name] = dict(time_s=ex.time_s, wan_mb=ex.wan_bytes / 1e6,
                                   sites=ex.n_sites, migration=mig)
            base = max(stats["geolayer"]["time_s"], 1e-12)
            for name, s_ in stats.items():
                rows.append(csv_row(
                    f"fig13-15_{ds}_{algo}_{name}", s_["time_s"] * 1e6,
                    f"norm_time={s_['time_s']/base:.2f} wan_mb={s_['wan_mb']:.2f} "
                    f"sites={s_['sites']} migration={s_['migration']:.2f}"))
            per_ds[algo] = stats
        out[ds] = per_ds
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
