"""Fig. 10 — placement algorithm execution time per strategy.

Paper: Random/Top trivial; ADP slowest (hypergraph partitioning rounds);
GeoLayer moderate (layered decomposition + cluster parallelism)."""
from __future__ import annotations

from typing import Dict

from .common import DATASETS, ONLINE_STRATEGIES, csv_row, make_setup, strategy_store, timed


def run(fast: bool = True) -> Dict[str, Dict[str, float]]:
    n_hist = 120 if fast else 600
    out = {}
    rows = []
    for ds in DATASETS[:1] if fast else DATASETS:
        setup = make_setup(ds, n_hist, 20)
        per = {}
        for strat in ONLINE_STRATEGIES:
            dt, store = timed(strategy_store, setup, strat)
            per[strat] = store.stats.placement_time_s
            rows.append(csv_row(f"fig10_{ds}_{strat}", per[strat] * 1e6,
                                f"layered_build_s={store.stats.build_time_s:.3f}"))
        out[ds] = per
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
