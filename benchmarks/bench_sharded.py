"""Sharded data plane: routed-request throughput over the CPU device mesh.

Backs the sharded-store PR's acceptance bar on a >= 100k-item store:

1. **Throughput sweep** (1/2/4/8 shards): the same 65% home / 35% remote
   request stream is served through ``ShardedGeoGraphStore.serve_batch``,
   which dispatches per-origin sub-batches to the owning shard and records
   each shard's busy seconds.  Two rates per config:

   - ``serial_rps``  — total requests / sum of shard busy time (one host
     doing all the work; sanity bar: sharding adds no dispatch overhead);
   - ``aggregate_rps`` — total requests / slowest shard's busy time, the
     deployment rate when each mesh shard is an independent host and the
     batch completes at the makespan (the repo's Eq. 1 straggler
     semantics).  Acceptance: >= 2x aggregate at 4 shards vs 1.

2. **Routing identity**: every config must return float-identical results
   for the shared probe batch — sharding is a data-plane refactor, not a
   routing change.

3. **WAN accounting**: per-shard ``serving.wan_bytes_link`` [src, dst]
   byte matrices from each shard registry, plus the fleet view folded by
   ``merged_metrics()``; merged counts must equal the routed totals.

Results land in ``BENCH_sharded.json`` at the repo root (CSV rows remain
the stdout contract).  The mesh is CPU-hosted: ``XLA_FLAGS`` below forces
8 host devices, so the bench runs identically in CI and on a laptop.
"""
from __future__ import annotations

import os

# must precede the first jax import anywhere in the process
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json
import pathlib
import time
from typing import Dict, List

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_synthetic_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.data.synthetic import community_graph
from repro.distributed.sharded_store import ShardedGeoGraphStore

from .common import csv_row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_sharded.json"
_N_DCS = 8
SHARD_COUNTS = [1, 2, 4, 8]


def _graph(n_vertices: int, seed: int = 0):
    return community_graph(
        n_vertices, n_communities=24, p_in=0.02, p_out=0.0005,
        seed=seed, n_dcs=_N_DCS,
    )


def _workload(g, n_patterns: int, seed: int = 0) -> Workload:
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=_N_DCS, n_hot_sources=64
    )
    return Workload.from_patterns(pats, g.n_items, _N_DCS)


def _request_stream(wl: Workload, n: int, seed: int = 7):
    """65% home / 35% remote origin mix over every DC of the mesh."""
    rng = np.random.default_rng(seed)
    pats = [p for p in wl.patterns if len(p.items)]
    reqs = []
    for _ in range(n):
        p = pats[int(rng.integers(0, len(pats)))]
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, _N_DCS))
        reqs.append((p.items, origin))
    return reqs


def _wan_matrix(snapshot: dict) -> List[List[float]]:
    """Dense [src, dst] byte matrix from ``serving.wan_bytes_link`` cells."""
    mat = np.zeros((_N_DCS, _N_DCS))
    for tag, cell in snapshot.get("serving.wan_bytes_link", {}).items():
        kv = dict(part.split("=") for part in tag.split(","))
        mat[int(kv["src"]), int(kv["dst"])] = cell["value"]
    return [[float(v) for v in row] for row in mat]


def _measure(n_vertices: int, n_patterns: int, stream, probe, batch: int) -> Dict:
    """One store build + serve sweep per shard count; graph/workload are
    rebuilt per config from the same seed because stores own their graph."""
    out: Dict[int, Dict] = {}
    for n_shards in SHARD_COUNTS:
        g = _graph(n_vertices)
        wl = _workload(g, n_patterns)
        store = ShardedGeoGraphStore(
            g, make_synthetic_env(_N_DCS, seed=0), wl,
            config=PlacementConfig(precache=False, dhd_steps=4),
            n_shards=n_shards, telemetry=True,
        )
        store.serve_batch(probe, observe=False)  # warm scratch + devices
        probe_res = store.serve_batch(probe, observe=False)
        busy: Dict[int, float] = {}
        t0 = time.perf_counter()
        for i in range(0, len(stream), batch):
            store.serve_batch(stream[i : i + batch], observe=False)
            for sid, dt in store.last_shard_seconds.items():
                busy[sid] = busy.get(sid, 0.0) + dt
        wall = time.perf_counter() - t0
        total = len(stream)
        serial = total / max(sum(busy.values()), 1e-12)
        aggregate = total / max(max(busy.values()), 1e-12)
        merged = store.merged_metrics()
        out[n_shards] = dict(
            n_shards=n_shards,
            n_items=int(g.n_items),
            requests=total,
            wall_s=wall,
            busy_s={str(k): float(v) for k, v in sorted(busy.items())},
            serial_rps=serial,
            aggregate_rps=aggregate,
            probe=[
                (r.served_by.tolist(), float(r.latency_s), float(r.wan_bytes))
                for r in probe_res
            ],
            merged_requests=float(
                merged["serving.requests"]["-"]["value"]
            ),
            wan_bytes_link=_wan_matrix(merged),
            wan_bytes_link_by_shard=[
                _wan_matrix(sh.registry.snapshot()) for sh in store.shards
            ],
        )
        print(csv_row(
            f"sharded{n_shards}",
            wall / total * 1e6,
            f"items={g.n_items};serial_rps={serial:.0f};"
            f"aggregate_rps={aggregate:.0f};"
            f"busy_max_s={max(busy.values()):.3f}",
        ))
    return out


def run(fast: bool = True, smoke: bool = False) -> None:
    # >= 100k items (vertices + edges) except in smoke — the acceptance
    # criterion is stated on a 100k-item store
    if smoke:
        n_vertices, n_patterns, n_requests, batch = 1500, 80, 1024, 256
    else:
        n_vertices = 12_000 if fast else 24_000
        n_patterns = 240
        n_requests = 8192 if fast else 16_384
        batch = 512
    wl = _workload(_graph(n_vertices), n_patterns)
    stream = _request_stream(wl, n_requests)
    probe = stream[:64]
    per_shard = _measure(n_vertices, n_patterns, stream, probe, batch)

    ref = per_shard[SHARD_COUNTS[0]]
    identity = all(
        len(cfg["probe"]) == len(ref["probe"])
        and all(
            a[0] == b[0] and a[1] == b[1] and a[2] == b[2]
            for a, b in zip(cfg["probe"], ref["probe"])
        )
        for cfg in per_shard.values()
    )
    # probe batches are served twice (warm + measured) outside the timed loop
    counted = all(
        cfg["merged_requests"] == float(n_requests + 2 * len(probe))
        for cfg in per_shard.values()
    )
    speedup4 = per_shard[4]["aggregate_rps"] / max(ref["aggregate_rps"], 1e-12)
    results = dict(
        n_dcs=_N_DCS,
        n_items=ref["n_items"],
        requests=n_requests,
        batch=batch,
        configs={
            str(k): {kk: vv for kk, vv in v.items() if kk != "probe"}
            for k, v in per_shard.items()
        },
        aggregate_speedup_4shard=speedup4,
        accept_identity_across_shards=bool(identity),
        accept_requests_counted=bool(counted),
        accept_agg_4shard_ge_2x=bool(speedup4 >= 2.0),
    )
    print(csv_row(
        "sharded_accept",
        0.0,
        f"identity={identity};counted={counted};agg4x={speedup4:.2f}x",
    ))
    assert identity, "sharded routing diverged from the 1-shard reference"
    assert counted, "merged registries lost routed requests"
    if smoke:
        # wider margin than the artifact flag: shared-runner timing noise
        # must not trip CI, but a serialized data plane (1.0x) still fails
        assert speedup4 >= 1.3, (
            f"4-shard aggregate speedup {speedup4:.2f}x < 1.3x"
        )
        print("# smoke OK (JSON artifact not rewritten)")
        return
    assert results["accept_agg_4shard_ge_2x"], (
        f"4-shard aggregate speedup {speedup4:.2f}x < 2x acceptance bar"
    )
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
