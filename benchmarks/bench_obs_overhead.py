"""Telemetry overhead on the batch-256 serving path.

The obs layer's acceptance bar: with the metrics registry + tracer enabled,
``GeoGraphStore.serve_batch`` at batch 256 must stay within 5% of the
disabled-telemetry wall time.  Both configurations are timed as the best of
many repeats (min, not median — the overhead question is about the cost the
instrumentation *adds*, and min-of-N is the standard way to strip scheduler
noise from a shared runner).

Also exports the enabled run's wall-clock span timeline
(``BENCH_obs.trace.json``) so the artifact proves the telemetry was really
on, and writes ``BENCH_obs.json`` with the measured ratio (non-smoke).
"""
from __future__ import annotations

import gc
import json
import math
import pathlib
import time
from typing import Dict

import numpy as np

from repro.obs import (
    MetricsRegistry,
    export_chrome_trace,
    get_registry,
    set_default_registry,
    text_dashboard,
)

from .bench_serving import _build_store, _request_stream
from .common import csv_row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_obs.json"
_TRACE_PATH = _JSON_PATH.with_name("BENCH_obs.trace.json")

BATCH = 256


def _best_time(fn, repeats: int) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return float(best)


def measure(
    store, reqs, repeats: int, trials: int = 4, budget: float = math.inf
) -> Dict[str, float]:
    """Interleaved A/B timing of ``serve_batch`` with telemetry off vs on.

    The overhead estimate is min-basis (see module docstring), but the min
    of N is itself a high-variance statistic on a contended runner — one
    trial can leave either configuration stuck above its floor for every
    sample.  Mins therefore accumulate across up to ``trials`` rounds
    (exactly min-of-``trials*repeats``, with an early exit once the
    estimate is under ``budget``); GC is paused during timing so collection
    pauses, which strike serves at random, don't masquerade as
    instrumentation cost."""
    serve = lambda: store.serve_batch(reqs, observe=False)
    serve()  # warm scratch allocations on both paths

    off_reg = MetricsRegistry(enabled=False)
    on_reg = MetricsRegistry(enabled=True)
    t_off = t_on = np.inf
    for _ in range(trials):
        gc.collect()
        gc.disable()
        try:
            # alternate the configurations so drift (thermal, page cache)
            # hits both
            for _ in range(repeats):
                old = set_default_registry(off_reg)
                try:
                    t_off = min(t_off, _best_time(serve, 1))
                finally:
                    set_default_registry(old)
                old = set_default_registry(on_reg)
                try:
                    t_on = min(t_on, _best_time(serve, 1))
                finally:
                    set_default_registry(old)
        finally:
            gc.enable()
        if t_on / t_off - 1.0 < budget:
            break
    return {
        "t_off_s": float(t_off),
        "t_on_s": float(t_on),
        "overhead": float(t_on / t_off - 1.0),
        "rps_off": len(reqs) / t_off,
        "rps_on": len(reqs) / t_on,
    }


def run(fast: bool = True, smoke: bool = False) -> None:
    if smoke:
        # bigger than the other smoke lanes on purpose: the telemetry cost
        # is ~fixed per batch, so a toy store understates the baseline and
        # overstates the relative overhead.  Deep 5-hop patterns put the
        # serve at ~6ms — the routing fast path halved batch-256 serving,
        # and with a short serve the 5% bar sinks below the fixed ~0.1-0.2ms
        # floor asymmetry a contended shared runner can pin on one variant
        n_vertices, n_patterns, repeats = 8000, 200, 40
        hops, branch = 5, 3
    else:
        n_vertices = 4000 if fast else 10_000
        n_patterns = 120 if fast else 360
        repeats = 60
        hops, branch = 3, 2
    store = _build_store(n_vertices, n_patterns, hops=hops, branch=branch)
    reqs = _request_stream(store, BATCH, seed=BATCH)
    m = measure(store, reqs, repeats, budget=0.05)
    print(csv_row(
        f"obs_overhead_batch{BATCH}",
        m["overhead"] * 100.0,
        f"t_off_us={m['t_off_s']*1e6:.0f};t_on_us={m['t_on_s']*1e6:.0f};"
        f"rps_on={m['rps_on']:.0f};rps_off={m['rps_off']:.0f}",
    ))

    # prove telemetry was really live: one enabled pass, export the span
    # timeline + dashboard counters
    old = set_default_registry(MetricsRegistry(enabled=True))
    try:
        store.tracer.reset()
        store.serve_batch(reqs, observe=False)
        snapshot = get_registry().snapshot()
        dash = text_dashboard(get_registry(), store.tracer)
        export_chrome_trace(store.tracer, str(_TRACE_PATH))
    finally:
        set_default_registry(old)
    assert "serving.requests" in snapshot, "enabled registry recorded nothing"
    assert len(store.tracer.records) > 0, "enabled tracer recorded no spans"

    results: Dict = {
        "batch": BATCH,
        "n_items": int(store.g.n_items),
        "repeats": repeats,
        **m,
        "n_spans": len(store.tracer.records),
        "accept_overhead_lt_5pct": bool(m["overhead"] < 0.05),
    }
    if smoke:
        assert m["overhead"] < 0.05, (
            f"telemetry overhead {m['overhead']*100:.1f}% exceeds the 5% "
            f"budget on the batch-{BATCH} serving path"
        )
        print(f"# smoke OK (JSON artifact not rewritten; wrote {_TRACE_PATH.name})")
        return
    print(dash)
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
