"""Fig. 9 — optimality gap vs the exact BIP optimum on a WIKI-vote-scale
graph.  Paper reports Gap = (C - C*)/C* = 7.8% with PuLP/CBC; we brute-force
the same optimum (coordinate-descent exact-improvement; DESIGN §9)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.optimal import solve_coordinate_descent
from repro.core.patterns import Workload, generate_khop_patterns
from repro.data.synthetic import make_benchmark_graph

from .common import csv_row
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore


def run(fast: bool = True) -> Dict[str, float]:
    # tiny instance so the exact solver is tractable
    g = make_benchmark_graph("wiki", seed=3, n_dcs=4)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    # the exact reference is only meaningful where the solver converges:
    # keep the instance tiny in both modes (paper's WIKI-vote plays the
    # same role — small enough for CBC)
    n_pat = 8
    pats = generate_khop_patterns(g, csr, n_pat, hops=2, branch=1, seed=7, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    sizes = g.item_size()
    primary = np.concatenate([g.partition, g.partition[g.src]]).astype(np.int64)

    store = GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False, dhd_steps=8))
    c_geo = store.cost().total
    _, c_star = solve_coordinate_descent(wl, env, sizes, primary, max_rounds=3)
    gap = (c_geo - c_star) / max(c_star, 1e-12) * 100.0
    print(csv_row("fig9_optimality_gap", 0.0,
                  f"C={c_geo:.4f} C*={c_star:.4f} gap={gap:.1f}% (paper: 7.8%)"))
    return {"C": c_geo, "C_star": c_star, "gap_pct": gap}


if __name__ == "__main__":
    run()
