"""Kernel micro-benchmarks: CPU-path timing (the jnp reference is the CPU
production path; Pallas kernels are TPU-target, validated in interpret mode
by tests/).  Reports us/call + achieved GB/s on the ref path."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

from .common import csv_row


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(fast: bool = True) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    rows = []
    out = {}
    # attention
    b, hq, hkv, s, d = 1, 8, 4, 512, 64
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    dt = _time(f, q, k, v)
    bytes_ = (q.nbytes + k.nbytes + v.nbytes) * 2
    rows.append(csv_row("kernel_attention_ref", dt * 1e6, f"GBps={bytes_/dt/1e9:.2f}"))
    out["attention"] = dt
    # dhd step
    n, kmax = 4096, 16
    cols = jnp.asarray(rng.integers(0, n, (n, kmax)), jnp.int32)
    vals = jnp.asarray(rng.random((n, kmax)), jnp.float32)
    heat = jnp.asarray(rng.random(n), jnp.float32)
    qq = jnp.zeros(n, jnp.float32)
    f = jax.jit(lambda h: ref.dhd_ell_ref(h, cols, vals, qq))
    dt = _time(f, heat)
    rows.append(csv_row("kernel_dhd_ref", dt * 1e6,
                        f"Medges_per_s={(n*kmax)/dt/1e6:.1f}"))
    out["dhd"] = dt
    # embedding bag
    V, D, B, L = 65536, 32, 1024, 20
    tab = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    f = jax.jit(lambda i: ref.embedding_bag_ref(tab, i))
    dt = _time(f, idx)
    rows.append(csv_row("kernel_embedding_bag_ref", dt * 1e6,
                        f"Mlookups_per_s={(B*L)/dt/1e6:.1f}"))
    out["embedding_bag"] = dt
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
