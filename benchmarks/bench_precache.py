"""Fig. 12 — pre-caching hit rate vs heat threshold quantile theta.

Paper: 50-60% quantile already reaches near-optimal hit rates (skewed
access).  Hit = test-pattern item served locally at the requesting DC."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore

from .common import csv_row, make_setup


def run(fast: bool = True) -> Dict[float, float]:
    setup = make_setup("snb", 150 if fast else 500, 50 if fast else 150)
    out = {}
    rows = []
    for theta_q in [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]:
        cfg = PlacementConfig(precache=True, theta_quantile=theta_q, dhd_steps=8)
        store = GeoGraphStore(setup.g, setup.env, setup.workload, config=cfg)
        hits = total = 0
        for p in setup.test_patterns:
            origin = int(np.argmax(p.r_py))
            local = store.state.delta[p.items, origin]
            hits += int(local.sum())
            total += len(p.items)
        out[theta_q] = hits / max(total, 1)
        rows.append(csv_row(f"fig12_theta_{theta_q:.1f}", 0.0, f"hit_rate={out[theta_q]:.3f}"))
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
