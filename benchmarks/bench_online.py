"""Fig. 7 — normalized online pattern-request response time per dataset.

Paper result: GeoLayer 3.4x over Random-3, 2.8x over Top-3, 1.8x over ADP,
1.6x over DCD (averaged).  Reports latency normalized to GeoLayer (=1.0).
"""
from __future__ import annotations

from typing import Dict

from .common import (
    DATASETS,
    ONLINE_STRATEGIES,
    csv_row,
    make_setup,
    mean_online_latency,
    strategy_store,
    timed,
)


def run(fast: bool = True) -> Dict[str, Dict[str, float]]:
    n_hist, n_test = (120, 40) if fast else (600, 150)
    out: Dict[str, Dict[str, float]] = {}
    rows = []
    for ds in DATASETS:
        setup = make_setup(ds, n_hist, n_test)
        lat: Dict[str, float] = {}
        for strat in ONLINE_STRATEGIES:
            dt, store = timed(strategy_store, setup, strat)
            l = mean_online_latency(store, setup.test_patterns)
            lat[strat] = l
            rows.append(csv_row(f"fig7_{ds}_{strat}", l * 1e6, f"build_s={dt:.2f}"))
        base = max(lat["geolayer"], 1e-9)
        out[ds] = {s: lat[s] / base for s in ONLINE_STRATEGIES}
    for ds, norm in out.items():
        speeds = {s: f"{v:.2f}x" for s, v in norm.items()}
        rows.append(csv_row(f"fig7_{ds}_normalized", 0.0, str(speeds)))
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
