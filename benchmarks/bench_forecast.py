"""Predictive pre-staging vs reactive placement on follow-the-sun traffic.

The demand-plane PR's acceptance bar: replay seeded diurnal request traces
(:func:`repro.data.synthetic.diurnal_demand_trace` — a von-Mises traffic
bump whose peak sweeps across the DCs once per period, hot item set rotating
with it) through two :class:`~repro.serve.MaintenancePolicy` configurations
over the same store build:

  * ``reactive``   — periodic flushes planned against the demand plane's
    *measured* EWMA view (``heat_source="measured"``): chases the traffic
    already served, so it is exactly one reaction lag behind every peak
    handoff.
  * ``predictive`` — the same measured flushes **plus** forecast-driven
    pre-staging: a :class:`~repro.demand.SeasonalForecaster` (period = the
    8 demand windows per diurnal cycle) predicts each origin's intensity one
    window ahead and ``begin_flush`` pre-stages the implied replicas into
    idle gaps before the demand arrives (adds only, epoch guards unchanged).

The scored statistic is p99 latency in the **handoff windows** — the
analytic instants midway between consecutive DC peaks, cycles >= 1 only (the
seasonal model spends cycle 0 learning) — where a reactive placement is
stalest.  Acceptance (recorded in ``BENCH_forecast.json``): predictive beats
reactive on handoff p99 for >= 2 seeded traces at equal throughput (ratio
>= 0.95).  The ``--smoke`` lane asserts this in CI in a few seconds.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph, diurnal_demand_trace
from repro.demand import EWMAForecaster, PersistenceForecaster, SeasonalForecaster
from repro.serve import (
    AdmissionConfig,
    AdmissionController,
    MaintenanceConfig,
    MaintenancePolicy,
    StoreClient,
)

from .common import csv_row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_forecast.json"

# 8 demand windows per diurnal period: the seasonal forecaster's cycle length
WINDOWS_PER_PERIOD = 8


def _build_store(
    n_vertices: int, n_patterns: int, window_s: float, seed: int
) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=16, p_in=0.03, p_out=0.0008,
        seed=seed, n_dcs=5,
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    # the replayed trace is read-only: keeping the synthetic workload's write
    # rates would charge every replica for writes the trace never issues and
    # price all demand-driven adds out of the Eq. 14 benefit model
    wl.w_xy[:] = 0.0
    store = GeoGraphStore(
        g, env, wl,
        config=PlacementConfig(precache=False, dhd_steps=4),
        demand_window_s=window_s,
    )
    # demand fades fast between peaks and sparsifies to exact zero, so
    # theta_drop can actually evict the previous region's replicas (a pure
    # EWMA never reaches zero and "serving" replicas are never droppable)
    store.demand.rate_alpha = 0.5
    store.demand.rate_floor = 0.05
    return store


def _policy(store, mode: str, window_s: float) -> MaintenancePolicy:
    common = dict(
        window_s=2.0,
        budget_frac=0.05,
        flush_every_s=window_s,
        heat_source="measured",
        plan_kw=dict(theta_add=0.3, theta_drop=0.25),
    )
    if mode == "reactive":
        cfg = MaintenanceConfig(**common)
    elif mode == "predictive":
        cfg = MaintenanceConfig(
            predictive=True,
            forecaster=SeasonalForecaster(period=WINDOWS_PER_PERIOD),
            prestage_horizon=1,
            prestage_theta_add=0.3,
            **common,
        )
    else:
        raise ValueError(mode)
    return MaintenancePolicy(store, cfg)


def _run_mode(
    store: GeoGraphStore,
    trace: List[Tuple[float, np.ndarray, int, int, Optional[float]]],
    handoffs: np.ndarray,
    mode: str,
    window_s: float,
    period_s: float,
) -> Dict:
    policy = _policy(store, mode, window_s)
    ctl = AdmissionController(
        store,
        AdmissionConfig(policy="greedy", fairness="fifo", max_batch=16),
        policy=policy,
    )
    client = StoreClient(ctl)
    for t, items, origin, prio, deadline in trace:
        client.submit(items, origin, deadline_s=deadline, priority=prio, at=t)
    done = ctl.run_until_idle()
    assert len(done) == len(trace)
    n_dcs = store.env.n_dcs
    # score the handoff windows of cycles >= 1 (cycle 0 is warm-up /
    # seasonal-learning for both modes); window half-width = a quarter of
    # the peak-to-peak spacing, centred on the analytic handoff instant
    half = period_s / (4.0 * n_dcs)
    scored = [h for h in handoffs if h >= period_s]
    lat = np.array([h.latency_s for h in done])
    t_sub = np.array([h.t_submit for h in done])
    sel = np.zeros(len(done), dtype=bool)
    for h in scored:
        sel |= np.abs(t_sub - h) <= half
    hand = lat[sel]
    m = ctl.metrics()
    out = {
        "p99_handoff_s": float(np.quantile(hand, 0.99)) if len(hand) else 0.0,
        "p50_handoff_s": float(np.quantile(hand, 0.50)) if len(hand) else 0.0,
        "n_handoff_requests": int(sel.sum()),
        "p99_s": float(np.quantile(lat, 0.99)),
        "p50_s": float(np.quantile(lat, 0.50)),
        "throughput_rps": m["throughput_rps"],
        "deadline_misses": m["deadline_misses"],
        "idle_s": m["idle_s"],
        "n_flushes": policy.n_flushes,
        "n_waves": policy.n_waves,
        "n_prestage_flushes": policy.n_prestage_flushes,
        "prestage_hits": policy.prestage_hits,
        "prestage_wasted": policy.prestage_wasted,
        "demand_windows": store.demand.window_index,
    }
    return out


def _forecaster_backtest(store: GeoGraphStore) -> Dict[str, float]:
    """One-step-ahead MAE of each forecaster over the realized intensity
    history (same series the predictive run planned against)."""
    series = np.stack(store.demand.history)  # [W, D]
    W, D = series.shape
    models = {
        "persistence": PersistenceForecaster(),
        "ewma": EWMAForecaster(),
        "seasonal": SeasonalForecaster(period=WINDOWS_PER_PERIOD),
    }
    start = WINDOWS_PER_PERIOD  # give every model one full cycle of history
    out = {}
    for name, model in models.items():
        errs = [
            abs(model.forecast(series[:t, d], 1) - series[t, d])
            for t in range(start, W)
            for d in range(D)
        ]
        out[name] = float(np.mean(errs)) if errs else 0.0
    return out


def run(fast: bool = True, smoke: bool = False) -> None:
    if smoke:
        n_vertices, n_patterns, n_req, seeds = 900, 48, 1400, (3, 4)
    else:
        n_vertices = 2000 if fast else 6000
        n_patterns = 64 if fast else 160
        n_req = 3000 if fast else 10000
        seeds = (3, 4, 5)
    period_s = 48.0
    n_periods = 3
    window_s = period_s / WINDOWS_PER_PERIOD

    results: Dict = {
        "period_s": period_s,
        "n_periods": n_periods,
        "demand_window_s": window_s,
        "n_requests": n_req,
        "seeds": {},
    }
    wins = []
    for seed in seeds:
        store_builds = {}
        for mode in ("reactive", "predictive"):
            # fresh, identical store per mode: both start from the same
            # placement and see the same trace
            store = _build_store(n_vertices, n_patterns, window_s, seed)
            pats = [p for p in store.workload.patterns if len(p.items)]
            trace, handoffs = diurnal_demand_trace(
                pats, store.env.n_dcs, n_req, period_s,
                n_periods=n_periods, locality=1.0,
                seed=seed + 100, deadline_s=0.5,
            )
            store_builds[mode] = _run_mode(
                store, trace, handoffs, mode, window_s, period_s
            )
            if mode == "predictive":
                store_builds["forecaster_mae"] = _forecaster_backtest(store)
        row = store_builds
        r, p = row["reactive"], row["predictive"]
        row["p99_handoff_win"] = r["p99_handoff_s"] / max(p["p99_handoff_s"], 1e-12)
        row["throughput_ratio"] = p["throughput_rps"] / max(r["throughput_rps"], 1e-12)
        won = (
            p["p99_handoff_s"] < r["p99_handoff_s"]
            and row["throughput_ratio"] >= 0.95
        )
        if won:
            wins.append(seed)
        results["seeds"][str(seed)] = row
        print(csv_row(
            f"forecast_seed{seed}",
            p["p99_handoff_s"] * 1e6,
            f"reactive_p99h_ms={r['p99_handoff_s']*1e3:.2f};"
            f"predictive_p99h_ms={p['p99_handoff_s']*1e3:.2f};"
            f"win={row['p99_handoff_win']:.2f}x;"
            f"tput_ratio={row['throughput_ratio']:.3f};"
            f"prestage_hit={p['prestage_hits']};"
            f"prestage_wasted={p['prestage_wasted']}",
        ))

    results["accept_win_seeds"] = wins
    results["accept_predictive_beats_reactive_ge_2_seeds"] = len(wins) >= 2
    if smoke:
        assert len(wins) >= 2, (
            "predictive pre-staging must beat reactive placement on handoff "
            f"p99 at equal throughput for >= 2 seeds; wins={wins}: "
            + json.dumps({
                s: {m: row[m]["p99_handoff_s"] for m in ("reactive", "predictive")}
                for s, row in results["seeds"].items()
            })
        )
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name} (win seeds: {wins})")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
