"""Batched serving path: throughput sweep + route-index patch vs full reroute.

Two measurements back the serving PR's acceptance bar:

1. **Batch-size sweep** (1 -> 1024 requests): wall time of the per-pattern
   ``route_online`` Python loop vs the vectorized ``route_online_batch`` on
   identical request sets.  Acceptance: >= 5x request throughput at batch 256.
2. **Post-migration routing refresh** on a ~10k-item graph: patching only the
   move-set rows through ``RouteIndex.apply_moves`` vs re-deriving the whole
   table with ``route_nearest``.  Acceptance: the patch wins.

Results additionally land in ``BENCH_serving.json`` at the repo root so the
perf trajectory is recorded across PRs (CSV rows remain the stdout contract).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

import numpy as np

from repro.core.cost import PlacementState
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.route_index import RouteIndex
from repro.core.routing import route_online, route_online_batch
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.streaming import DeltaGraph, random_churn_batch

from .common import csv_row, timed

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def _build_store(n_vertices: int, n_patterns: int, seed: int = 0) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=20, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=64
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False))


def _request_stream(store: GeoGraphStore, n: int, seed: int = 0):
    """Sampled pattern requests with the 65% home / 35% remote origin mix."""
    rng = np.random.default_rng(seed)
    pats = [p for p in store.workload.patterns if len(p.items)]
    d = store.env.n_dcs
    reqs = []
    for _ in range(n):
        p = pats[int(rng.integers(0, len(pats)))]
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, d))
        reqs.append((p.items, origin))
    return reqs


def _median_time(fn, repeats: int = 5):
    ts, out = [], None
    for _ in range(repeats):
        dt, out = timed(fn)
        ts.append(dt)
    return float(np.median(ts)), out


def _sweep(store: GeoGraphStore, sizes: List[int], results: Dict) -> None:
    for bs in sizes:
        reqs = _request_stream(store, bs, seed=bs)
        t_single, singles = _median_time(
            lambda: [route_online(store.lg, store.state, it, o) for it, o in reqs]
        )
        t_batch, batch = _median_time(
            lambda: route_online_batch(store.lg, store.state, reqs)
        )
        assert all(
            np.array_equal(s.served_by, b.served_by) for s, b in zip(singles, batch)
        ), "batch path diverged from route_online"
        speedup = t_single / max(t_batch, 1e-12)
        rps_single = bs / max(t_single, 1e-12)
        rps_batch = bs / max(t_batch, 1e-12)
        results["batch_sweep"].append(
            dict(batch=bs, t_single_s=t_single, t_batch_s=t_batch,
                 rps_single=rps_single, rps_batch=rps_batch, speedup=speedup)
        )
        print(csv_row(
            f"serving_batch{bs}",
            t_batch / bs * 1e6,
            f"speedup={speedup:.1f}x;rps_batch={rps_batch:.0f};rps_single={rps_single:.0f}",
        ))


def _synthetic_moves(store: GeoGraphStore, n_moves: int, rng) -> tuple:
    """A representative migration move-set (mixed adds/drops) applied to a
    copy of the current placement.  Used when the cost planner legitimately
    proposes nothing (byte-scale item sizes make adds uneconomical), since
    the measurement here is the routing-refresh cost, not planner yield."""
    from repro.streaming.migration import Move

    delta = store.state.delta.copy()
    moves = []
    I = delta.shape[0]
    for x in rng.choice(I, size=min(n_moves * 2, I), replace=False):
        x = int(x)
        row = delta[x]
        if row.sum() >= 2 and rng.random() < 0.5:
            dc = int(np.where(row)[0][-1])
            kind = "drop"
            delta[x, dc] = False
        else:
            off = np.where(~row)[0]
            if not len(off):
                continue
            dc = int(rng.choice(off))
            kind = "add"
            delta[x, dc] = True
        moves.append(Move(x, dc, kind, 0.0, 0.0))
        if len(moves) >= n_moves:
            break
    return delta, moves


def _patch_vs_reroute(store: GeoGraphStore, results: Dict, n_flushes: int) -> None:
    """Churn -> migration flush; compare the index patch done inside
    ``apply_plan`` with a full ``route_nearest`` re-derivation of the same
    final placement."""
    rng = np.random.default_rng(3)
    store._delta_graph = DeltaGraph(store.g)
    patch_ts, full_ts, n_moves = [], [], 0
    trials = []  # (pre_nearest, pre_second, final_delta, moves)
    for i in range(n_flushes):
        store.apply_updates(random_churn_batch(store._delta_graph, 0.01, rng))
        # snapshot the index *before* the flush patches it, so the replay
        # re-applies the move-set from the same starting point
        pre_n = store.route_index.nearest.copy()
        pre_s = store.route_index.second.copy()
        plan = store.flush_migrations(theta_add=0.5, theta_drop=0.15)
        if plan.moves:
            trials.append((pre_n, pre_s, store.state.delta.copy(), plan.moves))
    synthetic = not trials
    if synthetic:
        for i in range(n_flushes):
            delta, moves = _synthetic_moves(store, 512, rng)
            trials.append(
                (store.route_index.nearest.copy(),
                 store.route_index.second.copy(), delta, moves)
            )
    for pre_n, pre_s, delta, moves in trials:
        n_moves += len(moves)
        idx = RouteIndex(store.env, delta.shape[0])
        idx.nearest, idx.second = pre_n, pre_s
        t0 = time.perf_counter()
        idx.apply_moves(delta, moves)
        patch_ts.append(time.perf_counter() - t0)
        ref = PlacementState(delta, store.state.route.copy())
        t0 = time.perf_counter()
        ref.route_nearest(store.env)
        full_ts.append(time.perf_counter() - t0)
        assert np.array_equal(idx.nearest, ref.route), "patch != full reroute"
    t_patch = float(np.median(patch_ts)) if patch_ts else 0.0
    t_full = float(np.median(full_ts)) if full_ts else 0.0
    speedup = t_full / max(t_patch, 1e-12)
    results["patch_vs_reroute"] = dict(
        n_items=int(store.g.n_items), n_moves=n_moves, synthetic_moves=synthetic,
        t_patch_s=t_patch, t_full_s=t_full, speedup=speedup,
    )
    print(csv_row(
        "serving_index_patch",
        t_patch * 1e6,
        f"items={store.g.n_items};moves={n_moves};synthetic={synthetic};"
        f"full_reroute_us={t_full * 1e6:.1f};speedup={speedup:.1f}x",
    ))


def run(fast: bool = True, smoke: bool = False) -> None:
    # >= 10k items (vertices + edges) even in fast mode — the acceptance
    # criterion for index patching is stated on a 10k-item graph
    if smoke:
        n_vertices, n_patterns, sizes = 1200, 60, [1, 64]
    else:
        n_vertices = 4000 if fast else 10_000
        n_patterns = 120 if fast else 360
        sizes = [1, 4, 16, 64, 256, 1024]
    store = _build_store(n_vertices, n_patterns)
    results: Dict = {
        "n_items": int(store.g.n_items),
        "n_dcs": int(store.env.n_dcs),
        "batch_sweep": [],
    }
    # warm both paths (first route_online_batch allocates scratch)
    route_online_batch(store.lg, store.state, _request_stream(store, 8))
    _sweep(store, sizes, results)
    at1 = next(r for r in results["batch_sweep"] if r["batch"] == 1)
    # batch-1 parity: the size-1 fast path dispatches straight to
    # route_online, so a lone request must not pay the batch machinery
    # (it used to: speedup 0.48 before the fast path)
    results["accept_batch1_parity"] = bool(at1["speedup"] >= 0.8)
    if smoke:
        # CI gate: wider margin than the artifact flag so shared-runner
        # timing noise can't trip it — the pre-fast-path behavior (0.48)
        # still fails cleanly
        assert at1["speedup"] >= 0.6, (
            f"batch-1 fast path lost parity with route_online "
            f"(speedup {at1['speedup']:.2f} < 0.6)"
        )
        at_big = next(r for r in results["batch_sweep"] if r["batch"] == 64)
        assert at_big["speedup"] > 1.0, "batched serving slower than the loop"
        print("# smoke OK (JSON artifact not rewritten)")
        return
    _patch_vs_reroute(store, results, n_flushes=4 if fast else 8)

    at256 = next(r for r in results["batch_sweep"] if r["batch"] == 256)
    results["accept_batch256_speedup_ge_5x"] = bool(at256["speedup"] >= 5.0)
    results["accept_patch_beats_full"] = bool(
        results["patch_vs_reroute"]["speedup"] > 1.0
        or results["patch_vs_reroute"]["n_moves"] == 0
    )
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
