"""Batched serving path: throughput sweeps + route-index patch vs reroute.

Measurements backing the serving PRs' acceptance bars:

1. **Batch-size sweep** (1 -> 1024 requests): wall time of the per-pattern
   ``route_online`` Python loop vs the vectorized ``route_online_batch`` on
   identical request sets.  Acceptance: >= 5x request throughput at batch 256.
2. **Fast-path lane** on a 100k+-item store: the kernels fast path
   (``route_online_batch(fast=True)`` — autotuned subset/tile expansion) vs
   both the numpy batch path and the scalar loop, identity-asserted request
   for request.  Acceptance (PR 8): >= 5x routed rps over the numpy scalar
   path at batch >= 256; 10x is the stretch flag.
3. **Post-migration routing refresh** on a ~10k-item graph: patching only the
   move-set rows through ``RouteIndex.apply_moves`` vs re-deriving the whole
   table with ``route_nearest``.  Acceptance: the patch wins.
4. ``--tune``: sweep the ``route_expand`` autotuner candidates on this host
   and write the winner table to ``BENCH_autotune.json`` (the CI artifact
   that records which impl each device picks).

Results additionally land in ``BENCH_serving.json`` at the repo root so the
perf trajectory is recorded across PRs (CSV rows remain the stdout contract).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

import numpy as np

from repro.core.cost import PlacementState
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.route_index import RouteIndex
from repro.core.routing import route_online, route_online_batch
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.debug.sanitize import maybe_attach
from repro.streaming import DeltaGraph, random_churn_batch

from .common import csv_row, timed

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"
_AUTOTUNE_PATH = (
    pathlib.Path(__file__).resolve().parents[1] / "BENCH_autotune.json"
)


def _build_store(
    n_vertices: int,
    n_patterns: int,
    seed: int = 0,
    hops: int = 3,
    branch: int = 2,
) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=20, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, hops=hops, branch=branch, seed=seed + 1,
        n_dcs=env.n_dcs, n_hot_sources=64,
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False))


def _request_stream(store: GeoGraphStore, n: int, seed: int = 0):
    """Sampled pattern requests with the 65% home / 35% remote origin mix."""
    rng = np.random.default_rng(seed)
    pats = [p for p in store.workload.patterns if len(p.items)]
    d = store.env.n_dcs
    reqs = []
    for _ in range(n):
        p = pats[int(rng.integers(0, len(pats)))]
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, d))
        reqs.append((p.items, origin))
    return reqs


def _median_time(fn, repeats: int = 5):
    ts, out = [], None
    for _ in range(repeats):
        dt, out = timed(fn)
        ts.append(dt)
    return float(np.median(ts)), out


def _sweep(store: GeoGraphStore, sizes: List[int], results: Dict) -> None:
    for bs in sizes:
        reqs = _request_stream(store, bs, seed=bs)
        t_single, singles = _median_time(
            lambda: [route_online(store.lg, store.state, it, o) for it, o in reqs]
        )
        t_batch, batch = _median_time(
            lambda: route_online_batch(store.lg, store.state, reqs)
        )
        assert all(
            np.array_equal(s.served_by, b.served_by) for s, b in zip(singles, batch)
        ), "batch path diverged from route_online"
        speedup = t_single / max(t_batch, 1e-12)
        rps_single = bs / max(t_single, 1e-12)
        rps_batch = bs / max(t_batch, 1e-12)
        results["batch_sweep"].append(
            dict(batch=bs, t_single_s=t_single, t_batch_s=t_batch,
                 rps_single=rps_single, rps_batch=rps_batch, speedup=speedup)
        )
        print(csv_row(
            f"serving_batch{bs}",
            t_batch / bs * 1e6,
            f"speedup={speedup:.1f}x;rps_batch={rps_batch:.0f};rps_single={rps_single:.0f}",
        ))


def _fast_sweep(store: GeoGraphStore, sizes: List[int], results: Dict) -> None:
    """100k+-item lane: kernels fast path vs numpy batch path vs scalar loop,
    identity-asserted per request (exact picks AND exact f64 latency)."""
    pats = [p for p in store.workload.patterns if len(p.items)]
    lane: Dict = {
        "n_items": int(store.g.n_items),
        "mean_pattern_items": float(np.mean([len(p.items) for p in pats])),
        "rows": [],
    }
    for bs in sizes:
        reqs = _request_stream(store, bs, seed=1000 + bs)
        t_numpy, base = _median_time(
            lambda: route_online_batch(store.lg, store.state, reqs, fast=False)
        )
        t_fast, got = _median_time(
            lambda: route_online_batch(store.lg, store.state, reqs, fast=True)
        )
        for b, f in zip(base, got):
            assert np.array_equal(b.served_by, f.served_by), "fast path diverged"
            assert b.latency_s == f.latency_s, "fast path latency not exact"
        t_scalar, _ = _median_time(
            lambda: [route_online(store.lg, store.state, it, o) for it, o in reqs],
            repeats=3,
        )
        row = dict(
            batch=bs,
            t_scalar_s=t_scalar,
            t_numpy_batch_s=t_numpy,
            t_fast_s=t_fast,
            rps_fast=bs / max(t_fast, 1e-12),
            speedup_vs_scalar=t_scalar / max(t_fast, 1e-12),
            speedup_vs_numpy_batch=t_numpy / max(t_fast, 1e-12),
        )
        lane["rows"].append(row)
        print(csv_row(
            f"serving_fast{bs}",
            t_fast / bs * 1e6,
            f"vs_scalar={row['speedup_vs_scalar']:.1f}x;"
            f"vs_numpy_batch={row['speedup_vs_numpy_batch']:.1f}x;"
            f"rps_fast={row['rps_fast']:.0f}",
        ))
    results["fast_sweep"] = lane
    big = [r for r in lane["rows"] if r["batch"] >= 256]
    results["accept_fast_batch256_ge_5x"] = bool(
        big and all(r["speedup_vs_scalar"] >= 5.0 for r in big)
    )
    results["stretch_fast_ge_10x"] = bool(
        big and any(r["speedup_vs_scalar"] >= 10.0 for r in big)
    )


def _packed_inputs(store: GeoGraphStore, reqs) -> Dict:
    """Flat + padded-tile inputs for ops-level route_expand candidates."""
    from repro.kernels import autotune

    lg, state = store.lg, store.state
    D = store.env.n_dcs
    lens = np.array([len(it) for it, _ in reqs], np.int64)
    origin = np.array([o for _, o in reqs], np.int64)
    items_all = np.concatenate([np.asarray(it) for it, _ in reqs])
    req_id = np.repeat(np.arange(len(reqs)), lens)
    bits_flat = (
        state.delta[items_all] @ (1 << np.arange(D)).astype(np.float32)
    ).astype(np.int32)
    r_pad = autotune.shape_bucket(len(reqs))
    k_pad = autotune.shape_bucket(int(lens.max()))
    pos = np.concatenate([np.arange(k) for k in lens]).astype(np.int64)
    bits = np.zeros((r_pad, k_pad), np.int32)
    bits[req_id, pos] = bits_flat
    szp = np.zeros((r_pad, k_pad), np.float32)
    szp[req_id, pos] = lg.g.item_size()[items_all]
    lens_p = np.zeros(r_pad, np.int32)
    lens_p[: len(reqs)] = lens
    origin_p = np.zeros(r_pad, np.int32)
    origin_p[: len(reqs)] = origin
    return dict(
        R=len(reqs), D=D, bits_flat=bits_flat, req_id=req_id, origin=origin,
        comp=lg.comp_of_dc, tile=(bits, szp, lens_p, origin_p,
                                  lg.comp_of_dc.astype(np.int32),
                                  store.env.rtt_s.astype(np.float32),
                                  (1.0 / store.env.bw_Bps_safe()).astype(np.float32)),
        signature=(r_pad, k_pad, D, lg.n_layers),
    )


def _autotune_lane(store: GeoGraphStore, results: Dict, batch: int) -> None:
    """Sweep route_expand candidates on this host; the winner lands in the
    in-process table (so the serving sweep above actually uses it on a
    re-run) and the full table is written to BENCH_autotune.json."""
    from repro.kernels import ops
    from repro.kernels.autotune import get_autotuner

    pi = _packed_inputs(store, _request_stream(store, batch, seed=77))
    tuner = get_autotuner()

    def runner(cfg):
        if cfg["impl"] == "subsets":
            ops.route_expand_subsets(
                pi["bits_flat"], pi["req_id"], pi["R"], pi["origin"], pi["comp"]
            )
        else:
            ops.route_expand_batch(
                *pi["tile"],
                use_kernel=cfg["impl"] == "kernel",
                block_r=int(cfg.get("block_r", 128)),
            )

    winner = tuner.sweep(
        "route_expand",
        pi["signature"],
        ops.route_expand_candidates(n_dcs=pi["D"]),
        runner,
    )
    _AUTOTUNE_PATH.write_text(tuner.dumps() + "\n")
    results["autotune"] = dict(
        device=tuner.device_kind(),
        signature=list(pi["signature"]),
        winner=winner,
    )
    print(csv_row(
        "serving_autotune", 0.0,
        f"device={tuner.device_kind()};winner={winner['impl']};"
        f"wrote={_AUTOTUNE_PATH.name}",
    ))


def _smoke_kernel_lane() -> None:
    """Deterministic CPU interpret-mode check: the Pallas kernel, the jitted
    oracle and the subset router agree on picks for a fixed seed."""
    from repro.kernels import ops
    from repro.kernels.route_expand import route_expand

    rng = np.random.default_rng(42)
    R, K, D, L = 8, 24, 5, 3
    lens = rng.integers(4, K + 1, R).astype(np.int32)
    bits = np.zeros((R, K), np.int32)
    sizes = np.zeros((R, K), np.float32)
    for r in range(R):
        k = int(lens[r])
        rep = rng.random((k, D)) < 0.4
        bits[r, :k] = (rep * (1 << np.arange(D))).sum(axis=1)
        sizes[r, :k] = rng.random(k).astype(np.float32) + 0.5
    origin = rng.integers(0, D, R).astype(np.int32)
    comp = np.stack([
        np.arange(D), np.arange(D) // 2, np.arange(D) // 4, np.zeros(D, np.int64)
    ])
    rtt = rng.random((D, D)).astype(np.float32) * 0.1
    rtt = rtt + rtt.T
    np.fill_diagonal(rtt, 0.0)
    ibw = np.full((D, D), 1e-9, np.float32)
    args = (bits, sizes, lens, origin, comp, rtt, ibw)
    want = ops.route_expand_batch(*args, use_kernel=False)
    got = tuple(np.asarray(o) for o in route_expand(*args, block_r=8, interpret=True))
    for r in range(R):
        k = int(lens[r])
        assert np.array_equal(got[0][r, :k], want[0][r, :k]), "kernel != oracle"
    req_id = np.repeat(np.arange(R), lens)
    bits_flat = np.concatenate([bits[r, : lens[r]] for r in range(R)]).astype(np.int64)
    served, _, _ = ops.route_expand_subsets(
        bits_flat, req_id, R, origin.astype(np.int64), comp
    )
    lo = 0
    for r in range(R):
        k = int(lens[r])
        assert np.array_equal(served[lo : lo + k], want[0][r, :k]), "subsets != oracle"
        lo += k
    print(csv_row("serving_kernel_smoke", 0.0, "kernel==oracle==subsets"))


def _synthetic_moves(store: GeoGraphStore, n_moves: int, rng) -> tuple:
    """A representative migration move-set (mixed adds/drops) applied to a
    copy of the current placement.  Used when the cost planner legitimately
    proposes nothing (byte-scale item sizes make adds uneconomical), since
    the measurement here is the routing-refresh cost, not planner yield."""
    from repro.streaming.migration import Move

    delta = store.state.delta.copy()
    moves = []
    I = delta.shape[0]
    for x in rng.choice(I, size=min(n_moves * 2, I), replace=False):
        x = int(x)
        row = delta[x]
        if row.sum() >= 2 and rng.random() < 0.5:
            dc = int(np.where(row)[0][-1])
            kind = "drop"
            delta[x, dc] = False
        else:
            off = np.where(~row)[0]
            if not len(off):
                continue
            dc = int(rng.choice(off))
            kind = "add"
            delta[x, dc] = True
        moves.append(Move(x, dc, kind, 0.0, 0.0))
        if len(moves) >= n_moves:
            break
    return delta, moves


def _patch_vs_reroute(store: GeoGraphStore, results: Dict, n_flushes: int) -> None:
    """Churn -> migration flush; compare the index patch done inside
    ``apply_plan`` with a full ``route_nearest`` re-derivation of the same
    final placement."""
    rng = np.random.default_rng(3)
    store._delta_graph = DeltaGraph(store.g)
    patch_ts, full_ts, n_moves = [], [], 0
    trials = []  # (pre_nearest, pre_second, final_delta, moves)
    for i in range(n_flushes):
        store.apply_updates(random_churn_batch(store._delta_graph, 0.01, rng))
        # snapshot the index *before* the flush patches it, so the replay
        # re-applies the move-set from the same starting point
        pre_n = store.route_index.nearest.copy()
        pre_s = store.route_index.second.copy()
        plan = store.flush_migrations(theta_add=0.5, theta_drop=0.15)
        if plan.moves:
            trials.append((pre_n, pre_s, store.state.delta.copy(), plan.moves))
    synthetic = not trials
    if synthetic:
        for i in range(n_flushes):
            delta, moves = _synthetic_moves(store, 512, rng)
            trials.append(
                (store.route_index.nearest.copy(),
                 store.route_index.second.copy(), delta, moves)
            )
    for pre_n, pre_s, delta, moves in trials:
        n_moves += len(moves)
        idx = RouteIndex(store.env, delta.shape[0])
        idx.nearest, idx.second = pre_n, pre_s
        t0 = time.perf_counter()
        idx.apply_moves(delta, moves)
        patch_ts.append(time.perf_counter() - t0)
        ref = PlacementState(delta, store.state.route.copy())
        t0 = time.perf_counter()
        ref.route_nearest(store.env)
        full_ts.append(time.perf_counter() - t0)
        assert np.array_equal(idx.nearest, ref.route), "patch != full reroute"
    t_patch = float(np.median(patch_ts)) if patch_ts else 0.0
    t_full = float(np.median(full_ts)) if full_ts else 0.0
    speedup = t_full / max(t_patch, 1e-12)
    results["patch_vs_reroute"] = dict(
        n_items=int(store.g.n_items), n_moves=n_moves, synthetic_moves=synthetic,
        t_patch_s=t_patch, t_full_s=t_full, speedup=speedup,
    )
    print(csv_row(
        "serving_index_patch",
        t_patch * 1e6,
        f"items={store.g.n_items};moves={n_moves};synthetic={synthetic};"
        f"full_reroute_us={t_full * 1e6:.1f};speedup={speedup:.1f}x",
    ))


def run(fast: bool = True, smoke: bool = False, tune: bool = False) -> None:
    # >= 10k items (vertices + edges) even in fast mode — the acceptance
    # criterion for index patching is stated on a 10k-item graph
    if smoke:
        n_vertices, n_patterns, sizes = 1200, 60, [1, 64]
    else:
        n_vertices = 4000 if fast else 10_000
        n_patterns = 120 if fast else 360
        sizes = [1, 4, 16, 64, 256, 1024]
    store = _build_store(n_vertices, n_patterns)
    # REPRO_SANITIZE=1 wires low-frequency runtime invariant checks into
    # every store mutation below (no-op otherwise) — the CI smoke lane runs
    # with it on, so the serving path exercises the sanitizer for free
    sanitizer = maybe_attach(store)
    results: Dict = {
        "n_items": int(store.g.n_items),
        "n_dcs": int(store.env.n_dcs),
        "batch_sweep": [],
    }
    # warm both paths (first route_online_batch allocates scratch)
    route_online_batch(store.lg, store.state, _request_stream(store, 8))
    _sweep(store, sizes, results)
    at1 = next(r for r in results["batch_sweep"] if r["batch"] == 1)
    # batch-1 parity: the size-1 fast path dispatches straight to
    # route_online, so a lone request must not pay the batch machinery
    # (it used to: speedup 0.48 before the fast path)
    results["accept_batch1_parity"] = bool(at1["speedup"] >= 0.8)
    if smoke:
        # CI gate: wider margin than the artifact flag so shared-runner
        # timing noise can't trip it — the pre-fast-path behavior (0.48)
        # still fails cleanly
        assert at1["speedup"] >= 0.6, (
            f"batch-1 fast path lost parity with route_online "
            f"(speedup {at1['speedup']:.2f} < 0.6)"
        )
        at_big = next(r for r in results["batch_sweep"] if r["batch"] == 64)
        assert at_big["speedup"] > 1.0, "batched serving slower than the loop"
        _smoke_kernel_lane()
        if tune:
            _autotune_lane(store, results, batch=64)
        if sanitizer is not None:
            sanitizer.check()  # explicit end-of-lane sweep of every invariant
            print(csv_row(
                "serving_sanitize", 0.0,
                f"checks_run={sanitizer.checks_run};invariants=ok",
            ))
        print("# smoke OK (BENCH_serving.json not rewritten)")
        return
    # fast-path lane on a 100k+-item store (bigger graph, deeper k-hop
    # patterns: ~124 items per request); the acceptance bar lives here
    big = _build_store(
        26_000, 160 if fast else 256, seed=0, hops=5, branch=2
    )
    assert big.g.n_items >= 100_000
    route_online_batch(
        big.lg, big.state, _request_stream(big, 8), fast=True
    )  # warm the jit/scratch
    _fast_sweep(big, [64, 256, 1024], results)
    if tune:
        _autotune_lane(big, results, batch=256)
    _patch_vs_reroute(store, results, n_flushes=4 if fast else 8)
    if sanitizer is not None:
        sanitizer.check()

    at256 = next(r for r in results["batch_sweep"] if r["batch"] == 256)
    results["accept_batch256_speedup_ge_5x"] = bool(at256["speedup"] >= 5.0)
    results["accept_patch_beats_full"] = bool(
        results["patch_vs_reroute"]["speedup"] > 1.0
        or results["patch_vs_reroute"]["n_moves"] == 0
    )
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument(
        "--tune", action="store_true",
        help="sweep route_expand candidates; write BENCH_autotune.json",
    )
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke, tune=args.tune)
