"""Adaptive admission control vs the fixed-batch FIFO frontend.

Simulated-clock serving comparison backing the control-plane PR's acceptance
bar: the same arrival traces are replayed through three batching policies of
the :class:`~repro.serve.AdmissionController` —

  * ``fixed``    — the fixed-batch FIFO frontend: global FIFO order, a drain
    dispatches only once ``max_batch`` requests are pending (trailing
    partial drain when arrivals end).  This is the deprecated
    ``GraphFrontend`` usage pattern (buffer, then flush full chunks).
  * ``greedy``   — work-conserving fixed cap (dispatch whenever free).
  * ``adaptive`` — the AIMD loop: batch target grows while measured latency
    keeps deadline slack, shrinks on violation; round-robin origin fairness.

Regimes: ``steady`` (Poisson-ish arrivals), ``bursty`` (synchronized arrival
bursts), ``mixed`` (steady with interactive + bulk priority classes).
Everything is simulated and seeded — results are exactly reproducible and
immune to shared-runner timing noise.

Acceptance (recorded in ``BENCH_scheduler.json``): adaptive beats fixed on
p99 latency in >= 2 regimes (bursty AND steady) while staying within 10% of
its throughput.  The ``--smoke`` lane asserts this in CI in a few seconds.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.serve import AdmissionConfig, AdmissionController, StoreClient

from .common import csv_row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_scheduler.json"


def _build_store(n_vertices: int, n_patterns: int, seed: int = 0) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=20, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=64
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False))


Trace = List[Tuple[float, np.ndarray, int, int, float]]  # t, items, origin, prio, deadline


def _pick(store, rng):
    pats = [p for p in store.workload.patterns if len(p.items)]
    p = pats[int(rng.integers(0, len(pats)))]
    home = int(np.argmax(p.r_py))
    origin = home if rng.random() < 0.65 else int(rng.integers(0, store.env.n_dcs))
    return p.items, origin


def make_trace(store, regime: str, n: int, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    out: Trace = []
    if regime == "steady":
        t = 0.0
        for _ in range(n):
            t += float(rng.exponential(0.004))
            items, origin = _pick(store, rng)
            out.append((t, items, origin, 0, 0.5))
    elif regime == "bursty":
        burst, period, t = 80, 0.5, 0.0
        while len(out) < n:
            for _ in range(min(burst, n - len(out))):
                items, origin = _pick(store, rng)
                out.append((t + float(rng.random()) * 1e-3, items, origin, 0, 0.5))
            t += period
    elif regime == "mixed":
        t = 0.0
        for _ in range(n):
            t += float(rng.exponential(0.004))
            items, origin = _pick(store, rng)
            if rng.random() < 0.7:
                out.append((t, items, origin, 0, 0.3))  # interactive
            else:
                out.append((t, items, origin, 1, 3.0))  # bulk
    else:
        raise ValueError(regime)
    return out


_POLICIES = {
    "fixed": dict(policy="fixed", fairness="fifo"),
    "greedy": dict(policy="greedy", fairness="fifo"),
    "adaptive": dict(policy="adaptive", fairness="round_robin"),
}


def run_policy(store, trace: Trace, policy: str, max_batch: int = 256) -> Dict:
    ctl = AdmissionController(
        store, AdmissionConfig(max_batch=max_batch, **_POLICIES[policy])
    )
    client = StoreClient(ctl)
    for t, items, origin, prio, deadline in trace:
        client.submit(items, origin, deadline_s=deadline, priority=prio, at=t)
    done = ctl.run_until_idle()
    assert len(done) == len(trace)
    m = ctl.metrics()
    by_prio: Dict[int, List[float]] = {}
    for h in done:
        by_prio.setdefault(h.priority, []).append(h.latency_s)
    m["p99_by_priority"] = {
        str(p): float(np.quantile(np.asarray(v), 0.99)) for p, v in sorted(by_prio.items())
    }
    del m["served_by_origin"]
    return m


def run(fast: bool = True, smoke: bool = False) -> None:
    if smoke:
        n_vertices, n_patterns, n_req = 800, 40, 500
    else:
        n_vertices = 2500 if fast else 8000
        n_patterns = 80 if fast else 240
        n_req = 2000 if fast else 8000
    store = _build_store(n_vertices, n_patterns)
    results: Dict = {
        "n_items": int(store.g.n_items),
        "n_requests_per_regime": n_req,
        "regimes": {},
    }
    for regime in ("bursty", "steady", "mixed"):
        trace = make_trace(store, regime, n_req, seed=13)
        row: Dict = {}
        for policy in ("fixed", "greedy", "adaptive"):
            m = run_policy(store, trace, policy)
            row[policy] = m
            print(csv_row(
                f"sched_{regime}_{policy}",
                m["p99_s"] * 1e6,
                f"p50_ms={m['p50_s']*1e3:.2f};p99_ms={m['p99_s']*1e3:.2f};"
                f"rps={m['throughput_rps']:.0f};misses={m['deadline_misses']};"
                f"mean_batch={m['mean_batch']:.1f}",
            ))
        row["p99_win_vs_fixed"] = row["fixed"]["p99_s"] / max(row["adaptive"]["p99_s"], 1e-12)
        row["throughput_ratio_vs_fixed"] = (
            row["adaptive"]["throughput_rps"] / max(row["fixed"]["throughput_rps"], 1e-12)
        )
        results["regimes"][regime] = row

    wins = [
        r for r, row in results["regimes"].items()
        if row["adaptive"]["p99_s"] < row["fixed"]["p99_s"]
        and row["throughput_ratio_vs_fixed"] >= 0.9
    ]
    results["accept_p99_win_regimes"] = wins
    results["accept_adaptive_beats_fixed_ge_2_regimes"] = bool(
        {"bursty", "steady"} <= set(wins)
    )
    if smoke:
        assert {"bursty", "steady"} <= set(wins), (
            "adaptive batching must beat the fixed-batch FIFO frontend on p99 "
            f"at >=2 regimes within 10% throughput; wins={wins}: "
            + json.dumps({r: {p: row[p]["p99_s"] for p in _POLICIES}
                          for r, row in results["regimes"].items()})
        )
        print("# smoke OK (JSON artifact not rewritten)")
        return
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
