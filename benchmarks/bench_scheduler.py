"""Adaptive admission control vs the fixed-batch FIFO frontend.

Simulated-clock serving comparison backing the control-plane PR's acceptance
bar: the same arrival traces are replayed through three batching policies of
the :class:`~repro.serve.AdmissionController` —

  * ``fixed``    — the fixed-batch FIFO frontend: global FIFO order, a drain
    dispatches only once ``max_batch`` requests are pending (trailing
    partial drain when arrivals end).  This is the retired FIFO-frontend
    usage pattern (buffer, then flush full chunks).
  * ``greedy``   — work-conserving fixed cap (dispatch whenever free).
  * ``adaptive`` — the AIMD loop: batch target grows while measured latency
    keeps deadline slack, shrinks on violation; round-robin origin fairness.

Regimes: ``steady`` (Poisson-ish arrivals), ``bursty`` (synchronized arrival
bursts), ``mixed`` (steady with interactive + bulk priority classes).
Everything is simulated and seeded — results are exactly reproducible and
immune to shared-runner timing noise.

Acceptance (recorded in ``BENCH_scheduler.json``): adaptive beats fixed on
p99 latency in >= 2 regimes (bursty AND steady) while staying within 10% of
its throughput.  The ``--smoke`` lane asserts this in CI in a few seconds.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.obs import MetricsRegistry, export_chrome_trace, set_default_registry
from repro.serve import (
    AdmissionConfig,
    AdmissionController,
    MaintenanceConfig,
    MaintenancePolicy,
    StoreClient,
)
from repro.streaming import DeltaGraph, random_churn_batch

from .common import csv_row

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_scheduler.json"
_TRACE_PATH = _JSON_PATH.with_name("BENCH_scheduler.trace.json")


def _build_store(n_vertices: int, n_patterns: int, seed: int = 0) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=20, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=64
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False))


Trace = List[Tuple[float, np.ndarray, int, int, float]]  # t, items, origin, prio, deadline


def _pick(store, rng):
    pats = [p for p in store.workload.patterns if len(p.items)]
    p = pats[int(rng.integers(0, len(pats)))]
    home = int(np.argmax(p.r_py))
    origin = home if rng.random() < 0.65 else int(rng.integers(0, store.env.n_dcs))
    return p.items, origin


def make_trace(store, regime: str, n: int, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    out: Trace = []
    if regime == "steady":
        t = 0.0
        for _ in range(n):
            t += float(rng.exponential(0.004))
            items, origin = _pick(store, rng)
            out.append((t, items, origin, 0, 0.5))
    elif regime == "bursty":
        burst, period, t = 80, 0.5, 0.0
        while len(out) < n:
            for _ in range(min(burst, n - len(out))):
                items, origin = _pick(store, rng)
                out.append((t + float(rng.random()) * 1e-3, items, origin, 0, 0.5))
            t += period
    elif regime == "mixed":
        t = 0.0
        for _ in range(n):
            t += float(rng.exponential(0.004))
            items, origin = _pick(store, rng)
            if rng.random() < 0.7:
                out.append((t, items, origin, 0, 0.3))  # interactive
            else:
                out.append((t, items, origin, 1, 3.0))  # bulk
    else:
        raise ValueError(regime)
    return out


_POLICIES = {
    "fixed": dict(policy="fixed", fairness="fifo"),
    "greedy": dict(policy="greedy", fairness="fifo"),
    "adaptive": dict(policy="adaptive", fairness="round_robin"),
}


def run_policy(store, trace: Trace, policy: str, max_batch: int = 256) -> Dict:
    ctl = AdmissionController(
        store, AdmissionConfig(max_batch=max_batch, **_POLICIES[policy])
    )
    client = StoreClient(ctl)
    for t, items, origin, prio, deadline in trace:
        client.submit(items, origin, deadline_s=deadline, priority=prio, at=t)
    done = ctl.run_until_idle()
    assert len(done) == len(trace)
    m = ctl.metrics()
    by_prio: Dict[int, List[float]] = {}
    for h in done:
        by_prio.setdefault(h.priority, []).append(h.latency_s)
    m["p99_by_priority"] = {
        str(p): float(np.quantile(np.asarray(v), 0.99)) for p, v in sorted(by_prio.items())
    }
    m["p99_by_origin"] = {str(o): v for o, v in m["p99_by_origin"].items()}
    del m["served_by_origin"]
    return m


def run_traced(n_req: int, seed: int = 13) -> Tuple[str, Dict]:
    """One telemetry-enabled control-plane run: churned store, adaptive
    policy, armed migration flush landing waves in the bursty idle gaps.

    Returns ``(chrome_trace_json, summary)``.  Everything runs on the
    simulated clock, so two calls with the same seed serialize to
    byte-identical trace exports — asserted by the caller."""
    # random partition (not the community graph): churn then leaves real
    # placement drift behind, so the flush actually produces transfer waves
    rng = np.random.default_rng(seed)
    n, m = 220, 1400
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    keep = src != dst
    g = Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, 4, n)
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 24, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4)
    )
    rng = np.random.default_rng(seed + 100)
    store._delta_graph = DeltaGraph(store.g)
    for _ in range(3):
        store.apply_updates(random_churn_batch(store._delta_graph, 0.02, rng))
    # transfer window sized to a handful of items so the flush splits into
    # several waves (each lands in its own idle gap)
    window = 3.0 * float(np.median(store.g.item_size())) / float(
        store.env.bw_Bps_safe().min()
    )
    old = set_default_registry(MetricsRegistry(enabled=True))
    try:
        policy = MaintenancePolicy(
            store,
            MaintenanceConfig(
                window_s=window,
                plan_kw=dict(theta_add=0.3, theta_drop=0.15),
                maintain_every_s=1.0,
                maintain_cost_s=1e-4,
            ),
        )
        ctl = AdmissionController(
            store, AdmissionConfig(policy="adaptive"), policy=policy
        )
        client = StoreClient(ctl)
        policy.request_flush()
        for t, items, origin, prio, deadline in make_trace(
            store, "bursty", n_req, seed=seed
        ):
            client.submit(items, origin, deadline_s=deadline, priority=prio, at=t)
        ctl.run_until_idle()
        text = export_chrome_trace(ctl.tracer)
    finally:
        set_default_registry(old)
    names = [s.name for s in ctl.tracer.records]
    summary = {
        "n_spans": len(names),
        "n_request_spans": names.count("request"),
        "n_wave_spans": names.count("migration_wave"),
        "n_waves_applied": policy.n_waves,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, summary


def run(fast: bool = True, smoke: bool = False) -> None:
    if smoke:
        n_vertices, n_patterns, n_req = 800, 40, 500
    else:
        n_vertices = 2500 if fast else 8000
        n_patterns = 80 if fast else 240
        n_req = 2000 if fast else 8000
    store = _build_store(n_vertices, n_patterns)
    results: Dict = {
        "n_items": int(store.g.n_items),
        "n_requests_per_regime": n_req,
        "regimes": {},
    }
    for regime in ("bursty", "steady", "mixed"):
        trace = make_trace(store, regime, n_req, seed=13)
        row: Dict = {}
        for policy in ("fixed", "greedy", "adaptive"):
            m = run_policy(store, trace, policy)
            row[policy] = m
            print(csv_row(
                f"sched_{regime}_{policy}",
                m["p99_s"] * 1e6,
                f"p50_ms={m['p50_s']*1e3:.2f};p99_ms={m['p99_s']*1e3:.2f};"
                f"rps={m['throughput_rps']:.0f};misses={m['deadline_misses']};"
                f"mean_batch={m['mean_batch']:.1f}",
            ))
        row["p99_win_vs_fixed"] = row["fixed"]["p99_s"] / max(row["adaptive"]["p99_s"], 1e-12)
        row["throughput_ratio_vs_fixed"] = (
            row["adaptive"]["throughput_rps"] / max(row["fixed"]["throughput_rps"], 1e-12)
        )
        results["regimes"][regime] = row

    wins = [
        r for r, row in results["regimes"].items()
        if row["adaptive"]["p99_s"] < row["fixed"]["p99_s"]
        and row["throughput_ratio_vs_fixed"] >= 0.9
    ]
    results["accept_p99_win_regimes"] = wins
    results["accept_adaptive_beats_fixed_ge_2_regimes"] = bool(
        {"bursty", "steady"} <= set(wins)
    )

    # telemetry-enabled run: nested request spans + migration-wave spans on
    # the simulated clock, exported as Chrome trace-event JSON (Perfetto).
    # Two identical runs must serialize byte-for-byte (sim-clock tracing is
    # deterministic) — this is the observability PR's acceptance bar.
    n_traced = 300 if smoke else n_req
    text_a, trace_summary = run_traced(n_traced)
    text_b, _ = run_traced(n_traced)
    trace_summary["deterministic"] = text_a == text_b
    assert trace_summary["deterministic"], (
        "sim-clock trace export must be byte-identical across identical runs"
    )
    assert trace_summary["n_request_spans"] > 0
    assert trace_summary["n_wave_spans"] > 0, (
        "traced run landed no migration waves; widen churn or tighten window"
    )
    _TRACE_PATH.write_text(text_a + "\n")
    trace_summary["file"] = _TRACE_PATH.name
    results["trace"] = trace_summary
    print(csv_row(
        "sched_trace",
        trace_summary["n_spans"],
        f"requests={trace_summary['n_request_spans']};"
        f"waves={trace_summary['n_wave_spans']};"
        f"deterministic={trace_summary['deterministic']}",
    ))

    if smoke:
        assert {"bursty", "steady"} <= set(wins), (
            "adaptive batching must beat the fixed-batch FIFO frontend on p99 "
            f"at >=2 regimes within 10% throughput; wins={wins}: "
            + json.dumps({r: {p: row[p]["p99_s"] for p in _POLICIES}
                          for r, row in results["regimes"].items()})
        )
        print(f"# smoke OK (JSON artifact not rewritten; wrote {_TRACE_PATH.name})")
        return
    _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    print(f"# wrote {_JSON_PATH.name}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
