"""Batched placement engine: arena vs sequential DHD competition + insert paths.

Two measurements back the placement PR's acceptance bar:

1. **Competition sweep** over (regions R, candidates C) pools: the legacy
   per-(candidate, region) path (``_dhd_competition`` — re-derives
   ``region_adjacency`` and runs a fresh diffusion per call) vs the
   :class:`~repro.core.placement.CompetitionArena` (adjacency hoisted once,
   ONE batched diffusion per pool).  Acceptance: >= 5x at R >= 32, C >= 4,
   with identical winners region-for-region.
2. **Incremental pattern insertion**: ``insert_patterns_incremental``
   (journaled replay + in-place route patch) vs the full ``insert_patterns``
   re-place at <= 5% new patterns.  Acceptance: >= 3x with identical replica
   sets and routes.

``--smoke`` runs tiny sizes for CI (prints CSV, asserts correctness and
speedup > 1, skips the JSON artifact); fast/full runs land in
``BENCH_placement.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

import numpy as np

from repro.core.dhd import DHDParams
from repro.core.graph import build_csr, build_ell
from repro.core.latency import make_paper_env
from repro.core.patterns import OverlapRegion, Pattern, Workload, generate_khop_patterns
from repro.core.placement import CompetitionArena, PlacementConfig, _dhd_competition
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.kernels import ops
from repro.obs import MetricsRegistry, set_default_registry

from .common import csv_row, timed

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_placement.json"


# ------------------------------------------------------- competition sweep
def _make_pool(n_regions: int, n_candidates: int, seed: int = 0):
    """A synthetic decomposition pool with exact (R, C) control.

    Regions partition a slab of the graph's vertices (disjoint Venn cells);
    candidates hold random vertex subsets elsewhere, so their super-node
    edges arise from real graph connectivity."""
    rng = np.random.default_rng(seed)
    n_v = max(40 * n_regions, 400)
    g = community_graph(
        n_v, n_communities=max(8, n_regions // 4), p_in=0.03, p_out=0.002,
        seed=seed, n_dcs=5,
    )
    verts = rng.permutation(g.n_nodes)
    slab = verts[: n_v // 2]
    groups = np.array_split(slab, n_regions)
    regions = [
        OverlapRegion(rid=i, key=(i,), items=np.sort(grp.astype(np.int64)), degree=1)
        for i, grp in enumerate(groups)
    ]
    pool_rest = verts[n_v // 2 :]
    cand = []
    for c in range(n_candidates):
        held = rng.choice(pool_rest, size=len(pool_rest) // n_candidates, replace=False)
        cand.append(
            (c, np.asarray([c % 5]), [np.sort(held.astype(np.int64))])
        )
    unit_r = rng.random(5).astype(np.float64) + 0.1
    return g, regions, cand, unit_r


def _competition_sweep(
    sweep: List, results: Dict, n_steps: int = 32, warm_sequential: bool = True
) -> None:
    params = DHDParams()
    for (R, C) in sweep:
        g, regions, cand, unit_r = _make_pool(R, C, seed=R * 131 + C)

        def sequential():
            return [
                _dhd_competition(r, cand, regions, g, params, n_steps, unit_r)
                for r in regions
            ]

        def batched():
            arena = CompetitionArena(regions, g, cand, params, n_steps)
            req = list(range(len(cand)))
            return [arena.winner(r.rid, req, unit_r) for r in regions]

        # warm both paths once so jit compilation is priced out of the
        # steady state the store actually runs in.  (The sequential path
        # re-traces its diffusion loop every call, so warming barely helps
        # it — that re-trace IS the measured legacy cost.  Smoke mode skips
        # its warm-up pass entirely to stay inside the CI budget.)
        batched()
        if warm_sequential:
            sequential()
        t_seq, win_seq = timed(sequential)
        t_bat, win_bat = timed(batched)
        assert win_seq == win_bat, f"arena diverged from sequential at R={R} C={C}"
        speedup = t_seq / max(t_bat, 1e-12)
        results["competition_sweep"].append(
            dict(regions=R, candidates=C, t_sequential_s=t_seq,
                 t_arena_s=t_bat, speedup=speedup)
        )
        print(csv_row(
            f"placement_arena_r{R}c{C}",
            t_bat / max(R, 1) * 1e6,
            f"speedup={speedup:.1f}x;seq_s={t_seq:.3f};arena_s={t_bat:.3f}",
        ))


# --------------------------------------------------- incremental insertion
def _build_store(n_vertices: int, n_patterns: int, seed: int = 0) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=12, p_in=0.02, p_out=0.0008, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=48
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=16)
    )


def _insert_bench(
    n_vertices: int, n_patterns: int, n_rounds: int, results: Dict
) -> None:
    full = _build_store(n_vertices, n_patterns)
    inc = _build_store(n_vertices, n_patterns)
    g, env = full.g, full.env
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    n_new = max(2, n_patterns // 20)  # <= 5% new patterns per round
    t_fulls, t_incs = [], []
    for rnd in range(n_rounds):
        fresh = generate_khop_patterns(
            g, csr, n_new, seed=1000 + rnd, n_dcs=env.n_dcs, n_hot_sources=48
        )
        new = [
            Pattern(10_000 + rnd * 1000 + i, p.items, p.r_py, p.w_py, p.eta)
            for i, p in enumerate(fresh)
        ]
        dt, _ = timed(lambda: full.insert_patterns(new))
        t_fulls.append(dt)
        dt, rep = timed(lambda: inc.insert_patterns_incremental(new))
        t_incs.append(dt)
        assert np.array_equal(full.state.delta, inc.state.delta), \
            "incremental insert diverged from full re-place"
        assert np.array_equal(full.state.route, inc.state.route)
    t_full = float(np.median(t_fulls))
    t_inc = float(np.median(t_incs))
    speedup = t_full / max(t_inc, 1e-12)
    results["incremental_insert"] = dict(
        n_vertices=n_vertices, n_items=int(g.n_items), n_patterns=n_patterns,
        n_new_per_round=n_new, new_fraction=n_new / n_patterns,
        n_rounds=n_rounds, t_full_s=t_full, t_incremental_s=t_inc,
        speedup=speedup, last_report=rep,
    )
    print(csv_row(
        "placement_incremental_insert",
        t_inc * 1e6,
        f"speedup={speedup:.1f}x;full_s={t_full:.3f};inc_s={t_inc:.3f};"
        f"new_frac={n_new / n_patterns:.3f}",
    ))


# ------------------------------------------------------- edge-cache efficacy
def _edge_cache_bench(results: Dict, n_sweeps: int, smoke: bool) -> None:
    """Tail-edge cache hit rate on repeated DHD sweeps of one placement graph.

    Streaming placement re-passes the SAME ELL + COO-tail adjacency arrays to
    ``ops.dhd_step`` every sweep; the host-side deduped edge rebuild is cached
    on array identity, so all sweeps after the first should hit.  Counts live
    in the metrics registry (per-run, resettable) — the old module-global
    leaked across benchmark runs and could never be trusted here."""
    g = community_graph(800, n_communities=8, p_in=0.04, p_out=0.002,
                        seed=7, n_dcs=5)
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    ell = build_ell(csr, max_degree=8)  # low cap: power-law rows spill to tail
    assert len(ell.tail_src) > 0, "edge-cache bench graph produced no tail"
    import jax.numpy as jnp

    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    ts, td, tv = (jnp.asarray(ell.tail_src), jnp.asarray(ell.tail_dst),
                  jnp.asarray(ell.tail_val))
    rng = np.random.default_rng(7)
    heat = jnp.asarray(rng.random(g.n_nodes), jnp.float32)
    q = jnp.asarray(rng.random(g.n_nodes) * 0.1, jnp.float32)
    old_reg = set_default_registry(MetricsRegistry(enabled=True))
    try:
        for _ in range(n_sweeps):
            heat = ops.dhd_step(heat, cols, vals, q, ts, td, tv, alpha=0.05)
        cache = ops.edge_cache_stats()
    finally:
        set_default_registry(old_reg)
    results["edge_cache"] = dict(n_sweeps=n_sweeps, n_tail=len(ell.tail_src),
                                 **cache)
    print(csv_row(
        "placement_edge_cache",
        cache["hit_rate"] * 100.0,
        f"hits={cache['hits']};misses={cache['misses']};"
        f"hit_rate={cache['hit_rate']:.3f};sweeps={n_sweeps}",
    ))
    if smoke:
        assert cache["hits"] >= n_sweeps - 1, \
            "repeated DHD sweeps missed the tail-edge cache"


def run(fast: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        sweep = [(8, 3)]
        insert_args = (500, 60, 1)
    elif fast:
        sweep = [(32, 4), (32, 8), (64, 8)]
        insert_args = (1500, 120, 3)
    else:
        sweep = [(32, 4), (32, 8), (64, 8), (128, 8)]
        insert_args = (4000, 240, 4)
    results: Dict = {"competition_sweep": []}
    _competition_sweep(sweep, results, n_steps=16 if smoke else 32,
                       warm_sequential=not smoke)
    _insert_bench(*insert_args, results)
    _edge_cache_bench(results, n_sweeps=8 if smoke else 32, smoke=smoke)

    big = [
        r for r in results["competition_sweep"]
        if r["regions"] >= 32 and r["candidates"] >= 4
    ]
    results["accept_arena_ge_5x"] = bool(big and all(r["speedup"] >= 5.0 for r in big))
    results["accept_incremental_ge_3x"] = bool(
        results["incremental_insert"]["speedup"] >= 3.0
    )
    if smoke:
        # CI gate: regressions fail fast, tiny sizes stay off the artifact
        assert all(r["speedup"] > 1.0 for r in results["competition_sweep"]), \
            "arena slower than sequential competition"
        assert results["incremental_insert"]["speedup"] > 1.0, \
            "incremental insert slower than full re-place"
        print("# smoke OK (JSON artifact not rewritten)")
    else:
        _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"# wrote {_JSON_PATH.name}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
