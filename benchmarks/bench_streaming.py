"""Dynamic-workload axis: incremental streaming updates vs full rebuild.

For a 10k-vertex community graph under random churn batches (edge/vertex
births and deaths), measures per-batch ``GeoGraphStore.apply_updates`` wall
time against a from-scratch rebuild of the final graph (compact + layered
graph + overlap-centric placement + reroute), plus routing parity: every
workload pattern must resolve with the same coverage on both stores, and the
post-churn mean online latency is reported per churn rate.

CSV derived fields: ``speedup`` (rebuild / incremental, acceptance >= 5x at
1% churn), ``miss_inc``/``miss_reb`` (total unresolved items — must match),
``lat_inc_ms``/``lat_reb_ms`` (mean straggler latency over served patterns).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.streaming import DeltaGraph, compact_workload, random_churn_batch

from .common import csv_row, timed


def _build_store(n_patterns: int, seed: int = 0):
    g = community_graph(
        10_000, n_communities=25, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=128
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(g, env, wl, config=PlacementConfig()), env


def _serve_all(store: GeoGraphStore, seed: int = 0) -> tuple:
    """Serve every pattern with the 65% home / 35% remote origin mix of
    ``benchmarks.common.mean_online_latency`` (paper's cross-border mix)."""
    rng = np.random.default_rng(seed)
    d = store.env.n_dcs
    miss, lats = 0, []
    for p in store.workload.patterns:
        if not len(p.items):
            continue
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, d))
        res = store.serve_online(p, origin)
        miss += res.n_missing
        lats.append(res.latency_s)
    return miss, float(np.mean(lats)) if lats else 0.0


def run(fast: bool = True) -> None:
    rates = [0.01] if fast else [0.002, 0.01, 0.05]
    n_batches = 4 if fast else 6
    store, env = _build_store(n_patterns=240)
    cfg = store.config
    rng = np.random.default_rng(7)
    store._delta_graph = DeltaGraph(store.g)

    # warm the jit caches so steady-state batch cost is measured
    for _ in range(2):
        store.apply_updates(random_churn_batch(store._delta_graph, rates[0], rng))

    for rate in rates:
        inc_times: List[float] = []
        for _ in range(n_batches):
            batch = random_churn_batch(store._delta_graph, rate, rng)
            dt, _rep = timed(store.apply_updates, batch)
            inc_times.append(dt)
        dt_mig, plan = timed(store.flush_migrations)
        t_inc = float(np.median(inc_times))

        # from-scratch rebuild of the *same* post-churn graph + workload
        def rebuild():
            gc, vmap, emap = store._delta_graph.compact()
            wl2 = compact_workload(store.workload, store.g.n_nodes, gc, vmap, emap)
            return GeoGraphStore(gc, env, wl2, config=cfg)

        t_reb, rebuilt = timed(rebuild)

        miss_inc, lat_inc = _serve_all(store)
        miss_reb, lat_reb = _serve_all(rebuilt)
        ok = store.constraints()
        derived = (
            f"speedup={t_reb / t_inc:.1f}x;miss_inc={miss_inc};miss_reb={miss_reb};"
            f"lat_inc_ms={lat_inc * 1e3:.1f};lat_reb_ms={lat_reb * 1e3:.1f};"
            f"migrations={plan.n_adds}+{plan.n_drops}drop;"
            f"routing_closed={ok['a_requested_routed'] and ok['b_pattern_route_on_replica']}"
        )
        print(csv_row(f"streaming_apply_churn{rate:g}", t_inc * 1e6, derived))
        print(csv_row(f"streaming_rebuild_churn{rate:g}", t_reb * 1e6, f"migrate_s={dt_mig:.3f}"))


if __name__ == "__main__":
    run(fast=True)
