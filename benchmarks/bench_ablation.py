"""Fig. 16 — ablation: RP+RR / RP+SR / LP+RR / LP+SR (placement x routing).

Paper: RP+SR 1.32-1.36x online; LP+RR 2.15-2.60x; LP+SR 3.26-3.66x; offline
(PageRank) RP+SR 1.47-2.50x, LP+RR 1.15-1.19x, LP+SR 2.95-3.88x."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import analytics
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore

from .common import csv_row, make_setup, mean_online_latency

GRID = {
    "RP+RR": ("random", "random"),
    "RP+SR": ("random", "stepwise"),
    "LP+RR": ("geolayer", "random"),
    "LP+SR": ("geolayer", "stepwise"),
}


def run(fast: bool = True) -> Dict[str, Dict[str, float]]:
    out = {}
    rows = []
    for ds in ["snb"] if fast else ["snb", "uk", "tw"]:
        setup = make_setup(ds, 120 if fast else 500, 40 if fast else 120)
        lat = {}
        pr_time = {}
        for name, (placement, routing) in GRID.items():
            cfg = PlacementConfig(precache=placement == "geolayer", dhd_steps=8)
            store = GeoGraphStore(setup.g, setup.env, setup.workload,
                                  config=cfg, placement=placement, routing=routing)
            lat[name] = mean_online_latency(store, setup.test_patterns)
            # offline: route all nodes, price a PageRank run
            req = np.arange(setup.g.n_nodes)
            if routing == "stepwise":
                plan = store.plan_offline(req, n_iters=15)
                site = plan.item_site[: setup.g.n_nodes].copy()
                site[site < 0] = setup.g.partition[site < 0]
            else:
                site = setup.g.partition.copy()  # random routing = in place
            ex = analytics.simulate_execution(setup.env, setup.g, site, 15, msg_bytes=192.0, edge_rate=5e8)
            pr_time[name] = ex.time_s
        base_on, base_off = lat["RP+RR"], pr_time["RP+RR"]
        speed = {
            n: dict(online=base_on / max(lat[n], 1e-12),
                    offline=base_off / max(pr_time[n], 1e-12))
            for n in GRID
        }
        out[ds] = speed
        for n, s_ in speed.items():
            rows.append(csv_row(f"fig16_{ds}_{n}", lat[n] * 1e6,
                                f"online={s_['online']:.2f}x offline={s_['offline']:.2f}x"))
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
