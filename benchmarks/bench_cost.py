"""Fig. 8 — cost metric breakdown (C^S, C^R, C^W, C^A) per strategy,
normalized to GeoLayer's total.  Paper: GeoLayer cuts total cost 60.8% vs
Random-3, 57.5% vs Top-3, 31.1% vs ADP, 28.1% vs DCD."""
from __future__ import annotations

from typing import Dict

from .common import DATASETS, ONLINE_STRATEGIES, csv_row, make_setup, strategy_store, timed


def run(fast: bool = True) -> Dict[str, Dict[str, Dict[str, float]]]:
    n_hist, n_test = (120, 30) if fast else (600, 150)
    out = {}
    rows = []
    for ds in (DATASETS if not fast else DATASETS[:2]):
        setup = make_setup(ds, n_hist, n_test)
        per = {}
        base_total = None
        for strat in ONLINE_STRATEGIES:
            dt, store = timed(strategy_store, setup, strat)
            c = store.cost().as_dict()
            per[strat] = c
            if strat == "geolayer":
                base_total = max(c["total"], 1e-12)
        for strat, c in per.items():
            norm = {k: v / base_total for k, v in c.items()}
            rows.append(csv_row(f"fig8_{ds}_{strat}", 0.0,
                                f"total={norm['total']:.3f} assoc={norm['assoc']:.3f} read={norm['read']:.3f}"))
        out[ds] = per
    print("\n".join(rows))
    return out


if __name__ == "__main__":
    run()
