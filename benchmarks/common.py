"""Shared benchmark harness: environments, graphs, workloads, strategies.

Scale knobs: ``fast`` (default in CI) uses reduced graph/pattern counts; the
``--full`` flag in benchmarks.run lifts them.  Graph families follow the
paper's Table III datasets structurally (DESIGN §9).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.graph import Graph, build_csr
from repro.core.latency import GeoEnvironment, make_paper_env
from repro.core.patterns import Pattern, Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import make_benchmark_graph

DATASETS = ["snb", "uk", "tw"]
ONLINE_STRATEGIES = ["geolayer", "random", "top", "adp", "dcd"]


@dataclasses.dataclass
class Setup:
    name: str
    g: Graph
    env: GeoEnvironment
    workload: Workload
    test_patterns: List[Pattern]


def make_setup(
    dataset: str,
    n_history: int = 240,
    n_test: int = 60,
    env: Optional[GeoEnvironment] = None,
    seed: int = 0,
    n_dcs: int = 5,
) -> Setup:
    g = make_benchmark_graph(dataset, seed=seed, n_dcs=n_dcs)
    env = env or make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_history, seed=seed + 1, n_dcs=env.n_dcs,
        n_hot_sources=max(24, g.n_nodes // 128),  # paper-style hot cores
    )
    history = pats
    # Test patterns follow the paper's setup: drawn from the *same* query
    # stream as the 1M-query history (the "additional 100k queries"), i.e.
    # mostly revisits of hot patterns with fresh variation at the fringe.
    rng = np.random.default_rng(seed + 77)
    fresh = generate_khop_patterns(
        g, csr, n_test, seed=seed + 1000, n_dcs=env.n_dcs,
        n_hot_sources=max(24, g.n_nodes // 128),
    )
    test: List[Pattern] = []
    for i in range(n_test):
        base = history[int(rng.integers(0, n_history))]
        keep = rng.random(len(base.items)) < 0.8
        items = base.items[keep]
        tail = fresh[i].items[: max(2, len(fresh[i].items) // 4)]
        items = np.unique(np.concatenate([items, tail]))
        test.append(
            Pattern(pid=10_000 + i, items=items, r_py=base.r_py,
                    w_py=base.w_py, eta=base.eta)
        )
    wl = Workload.from_patterns(history, g.n_items, env.n_dcs)
    return Setup(dataset, g, env, wl, test)


def build_store(
    setup: Setup, placement: str, routing: str, seed: int = 0
) -> GeoGraphStore:
    cfg = PlacementConfig(precache=placement == "geolayer", dhd_steps=8)
    return GeoGraphStore(
        setup.g, setup.env, setup.workload,
        config=cfg, placement=placement, routing=routing, seed=seed,
    )


def strategy_store(setup: Setup, strategy: str, seed: int = 0) -> GeoGraphStore:
    """Paper pairings: GeoLayer = LP+SR; Random-3/Top-3 random routing;
    ADP/DCD greedy set-cover routing."""
    routing = {"geolayer": "stepwise", "random": "random", "top": "random",
               "adp": "greedy", "dcd": "greedy"}[strategy]
    return build_store(setup, strategy, routing, seed)


def mean_online_latency(
    store: GeoGraphStore, patterns: List[Pattern], seed: int = 0
) -> float:
    """Serve each pattern from an origin drawn like the workload's
    (65% home DC, 35% remote — the paper's cross-border access mix)."""
    rng = np.random.default_rng(seed)
    d = store.env.n_dcs
    lats = []
    for p in patterns:
        home = int(np.argmax(p.r_py))
        origin = home if rng.random() < 0.65 else int(rng.integers(0, d))
        lats.append(store.serve_online(p, origin).latency_s)
    return float(np.mean(lats))


def timed(fn, *args, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
