"""Bandwidth-aware migration pipeline: planner speedup + link-budget waves.

Three measurements back the migration PR's acceptance bar:

1. **Planner vectorization** at ~16k items: median wall time of the
   ``[K, D]``-matrix ``plan_migrations`` vs the per-item legacy loops
   (``vectorized=False``) on the identical heat field.  The move-sets are
   asserted identical on every trial; acceptance: >= 10x.
2. **Transfer scheduling**: the accepted adds packed into per-(src, dst)
   :class:`TransferWave`s under ``env.bw_Bps * window_s`` link budgets —
   reports wave count / pipelined makespan and asserts no wave overloads a
   link (lone oversized transfers excepted, and counted).
3. **Wave-ordered apply**: ``store.flush_migrations(window_s=...)`` end to
   end (plan + schedule + per-wave RouteIndex patches + constraint guard).

Items carry MB-scale sizes here (item size is the WAN payload the pipeline
exists to budget); the byte-scale defaults of the other benches make every
add uneconomical and would leave the scheduler nothing to pack.

Results land in ``BENCH_migration.json`` (CSV rows remain the stdout
contract); ``--smoke`` runs tiny sizes, asserts the invariants, and leaves
the JSON artifact alone.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict

import numpy as np

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.streaming import DeltaGraph, random_churn_batch
from repro.streaming.delta_dhd import StreamingHeat
from repro.streaming.migration import plan_migrations, schedule_transfers

from .common import csv_row, timed

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_migration.json"

_MB = 1e6


def _build_store(n_vertices: int, n_patterns: int, seed: int = 0) -> GeoGraphStore:
    g = community_graph(
        n_vertices, n_communities=20, p_in=0.02, p_out=0.0005, seed=seed, n_dcs=5
    )
    rng = np.random.default_rng(seed + 7)
    # MB-scale payloads: the WAN transfer sizes the link budgets meter
    g.node_size = rng.uniform(0.5, 2.0, g.n_nodes).astype(np.float32) * _MB
    g.edge_size = rng.uniform(0.05, 0.2, g.n_edges).astype(np.float32) * _MB
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(
        g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs, n_hot_sources=64
    )
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    # a *stale* placement is the planner's real workload: random-3 replicas
    # disagree with the heat field everywhere, so both candidate pools (adds
    # near readers, cold drops) are dense — geolayer placement would leave
    # the planner nothing to fix right after build
    store = GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False), placement="random"
    )
    # a little churn so the heat field has genuinely drifted from placement
    rng = np.random.default_rng(seed + 11)
    store._delta_graph = DeltaGraph(store.g)
    store.apply_updates(random_churn_batch(store._delta_graph, 0.01, rng))
    return store


def _planning_inputs(store: GeoGraphStore):
    """The exact heat/aliveness derivation flush_migrations plans from."""
    if store._heat is None or store._heat.heat is None:
        store._heat = StreamingHeat()
        alive_e, w_e, q = store._heat_inputs()
        store._heat.rebuild(
            store.g.n_nodes, store.g.src[alive_e], store.g.dst[alive_e], w_e, q
        )
    vheat = store._heat.vertex_heat
    eheat = 0.5 * (vheat[store.g.src] + vheat[store.g.dst])
    alive = np.concatenate(
        [store._delta_graph.node_alive, store._delta_graph.edge_alive]
    )
    return np.concatenate([vheat, eheat]) * alive, alive


def _median_time(fn, repeats: int):
    ts, out = [], None
    for _ in range(repeats):
        dt, out = timed(fn)
        ts.append(dt)
    return float(np.median(ts)), out


def _plan_sweep(store: GeoGraphStore, results: Dict, repeats: int) -> None:
    heat, alive = _planning_inputs(store)
    budget = 0.05 * float(store.g.item_size().sum())
    kw = dict(theta_add=0.5, theta_drop=0.15, item_alive=alive)
    args = (
        store.g, store.env, store.state,
        store.workload.r_xy, store.workload.w_xy, heat, budget,
    )
    t_vec, p_vec = _median_time(
        lambda: plan_migrations(*args, vectorized=True, **kw), repeats
    )
    t_leg, p_leg = _median_time(
        lambda: plan_migrations(*args, vectorized=False, **kw), repeats
    )
    assert [(m.item, m.dc, m.kind, m.src, m.benefit) for m in p_vec.moves] == [
        (m.item, m.dc, m.kind, m.src, m.benefit) for m in p_leg.moves
    ], "vectorized planner diverged from the legacy move-set"
    speedup = t_leg / max(t_vec, 1e-12)
    results["planner"] = dict(
        n_items=int(store.g.n_items), n_candidates=int(p_vec.n_candidates),
        n_moves=len(p_vec.moves), n_adds=p_vec.n_adds, n_drops=p_vec.n_drops,
        t_vectorized_s=t_vec, t_legacy_s=t_leg, speedup=speedup,
    )
    print(csv_row(
        "migration_plan",
        t_vec * 1e6,
        f"items={store.g.n_items};cands={p_vec.n_candidates};"
        f"moves={len(p_vec.moves)};legacy_us={t_leg * 1e6:.0f};"
        f"speedup={speedup:.1f}x",
    ))


def _schedule_sweep(store: GeoGraphStore, results: Dict) -> float:
    heat, alive = _planning_inputs(store)
    budget = 0.05 * float(store.g.item_size().sum())
    plan = plan_migrations(
        store.g, store.env, store.state, store.workload.r_xy,
        store.workload.w_xy, heat, budget,
        theta_add=0.5, theta_drop=0.15, item_alive=alive,
    )
    # size the window off the busiest link so the packing genuinely
    # pipelines (~4 waves there) instead of trivially fitting in one
    link_bytes: Dict = {}
    for m in plan.moves:
        if m.kind == "add" and m.src >= 0 and m.src != m.dc:
            key = (m.src, m.dc)
            link_bytes[key] = link_bytes.get(key, 0.0) + m.wan_bytes
    if link_bytes:
        (s, d), busiest = max(link_bytes.items(), key=lambda kv: kv[1])
        window_s = busiest / (4.0 * float(store.env.bw_Bps[s, d]))
    else:
        window_s = 1.0
    t_sched, sched = _median_time(
        lambda: schedule_transfers(plan, store.env, window_s), 3
    )
    within = all(
        b.nbytes <= float(sched.link_budget[b.src, b.dst]) or b.n_transfers == 1
        for w in sched.waves for b in w.links
    )
    n_links = len({(b.src, b.dst) for w in sched.waves for b in w.links})
    results["schedule"] = dict(
        window_s=window_s, n_adds=plan.n_adds, n_waves=sched.n_waves,
        n_links=n_links, oversized=sched.oversized,
        wan_bytes=plan.wan_bytes, makespan_s=sched.makespan_s,
        t_schedule_s=t_sched, within_link_budgets=bool(within),
    )
    print(csv_row(
        "migration_schedule",
        t_sched * 1e6,
        f"adds={plan.n_adds};waves={sched.n_waves};links={n_links};"
        f"makespan_s={sched.makespan_s:.2f};within_budget={within}",
    ))
    return window_s


def _flush_end_to_end(store: GeoGraphStore, results: Dict, window_s: float) -> None:
    waves_seen = []
    dt, plan = timed(lambda: store.flush_migrations(
        window_s=window_s, theta_add=0.5, theta_drop=0.15,
        on_wave=lambda w: waves_seen.append(w.index),
    ))
    results["flush"] = dict(
        t_flush_s=dt, n_moves=len(plan.moves), n_waves=len(waves_seen),
        rolled_back=plan.rolled_back,
        makespan_s=plan.schedule.makespan_s if plan.schedule else 0.0,
    )
    print(csv_row(
        "migration_flush",
        dt * 1e6,
        f"moves={len(plan.moves)};waves={len(waves_seen)};"
        f"rolled_back={plan.rolled_back}",
    ))


def run(fast: bool = True, smoke: bool = False) -> Dict:
    if smoke:
        n_vertices, n_patterns, repeats = 800, 60, 2
    elif fast:
        # ~16k items (vertices + edges): the acceptance-criterion scale
        n_vertices, n_patterns, repeats = 4000, 120, 3
    else:
        n_vertices, n_patterns, repeats = 10_000, 360, 5
    store = _build_store(n_vertices, n_patterns)
    results: Dict = {"n_items": int(store.g.n_items), "n_dcs": int(store.env.n_dcs)}
    _plan_sweep(store, results, repeats)
    window_s = _schedule_sweep(store, results)
    _flush_end_to_end(store, results, window_s)

    results["accept_planner_ge_10x"] = bool(results["planner"]["speedup"] >= 10.0)
    results["accept_within_link_budgets"] = bool(
        results["schedule"]["within_link_budgets"]
    )
    if smoke:
        # CI gate: regressions fail fast, tiny sizes stay off the artifact
        assert results["planner"]["speedup"] > 2.0, \
            "vectorized planner lost its edge over the legacy loops"
        assert results["schedule"]["within_link_budgets"], \
            "a transfer wave overloaded a WAN link budget"
        assert results["schedule"]["n_waves"] >= 1 and results["flush"]["n_waves"] >= 1
        print("# smoke OK (JSON artifact not rewritten)")
    else:
        _JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
        print(f"# wrote {_JSON_PATH.name}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)
