"""Benchmark harness entry: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full]``
Prints ``name,us_per_call,derived`` CSV rows per the repo contract.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None, help="comma list of figure keys")
    args, _ = ap.parse_known_args()
    fast = not args.full

    from . import (
        bench_ablation,
        bench_cost,
        bench_exec_time,
        bench_forecast,
        bench_heterogeneity,
        bench_kernels,
        bench_migration,
        bench_obs_overhead,
        bench_offline,
        bench_online,
        bench_optimality,
        bench_placement,
        bench_precache,
        bench_scheduler,
        bench_serving,
        bench_sharded,
        bench_streaming,
    )

    suites = {
        "fig7_online": bench_online.run,
        "fig8_cost": bench_cost.run,
        "fig9_optimality": bench_optimality.run,
        "fig10_exec_time": bench_exec_time.run,
        "fig11_heterogeneity": bench_heterogeneity.run,
        "fig12_precache": bench_precache.run,
        "fig13_15_offline": bench_offline.run,
        "fig16_ablation": bench_ablation.run,
        "kernels": bench_kernels.run,
        "streaming": bench_streaming.run,
        "serving": bench_serving.run,
        "sharded": bench_sharded.run,
        "placement": bench_placement.run,
        "migration": bench_migration.run,
        "scheduler": bench_scheduler.run,
        "forecast": bench_forecast.run,
        "obs": bench_obs_overhead.run,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t_all = time.perf_counter()
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            fn(fast=fast)
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,FAILED:{type(e).__name__}:{e}")
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.perf_counter()-t_all:.1f}s")


if __name__ == "__main__":
    main()
